"""Multi-tenant report scoping: permission-bitmap plane == scalar oracle.

Differential contract (PR 7): with a GrantTable attached, every serving
query accepts ``subject=`` and returns exactly what a host fold filtered
by :meth:`GrantTable.visible_mask` returns — whether it is served from
the device store's packed permission bitsets (one fused AND inside the
mesh kernels) or from the host fallback. Also pins the maintenance
contract: pure-update churn patches the resident bitsets word-by-word
(``perm_word_scatters``), structural churn and grant mutations force a
re-materialization (``perm_materializations``), and the fallback
telemetry fixes (reason cleared on store-served success, one index
prefetch per ``du_many`` fallback batch) stay fixed.
"""
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, GrantTable,
                        HsmState, PolicyError)
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports

NOW = float(2 ** 20)          # f32-exact "now"


def _shards_mesh():
    from repro.launch.mesh import make_shards_mesh
    return make_shards_mesh()


def _entry(rng, i, **over):
    kw = dict(
        fid=i + 1, name=f"f{i + 1}", path=f"/p/d{i % 5}/f{i + 1}",
        type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
        size=int(rng.integers(0, 2 ** 12)) * 1024,
        blocks=int(rng.integers(0, 2 ** 10)),
        owner=f"user{int(rng.integers(0, 4))}",
        group=f"grp{int(rng.integers(0, 3))}",
        hsm_state=HsmState(int(rng.integers(0, 5))),
        atime=NOW - float(rng.integers(0, 10_000)),
        mtime=NOW - float(rng.integers(0, 10_000)))
    kw.update(over)
    return Entry(**kw)


def _random_catalog(rng, n, n_shards=8):
    cat = Catalog(n_shards=n_shards)
    cat.upsert_batch([_entry(rng, i) for i in range(n)])
    return cat


def _churn(cat, rng, n_total, k):
    for f in rng.choice(np.arange(1, n_total + 1), size=k, replace=False):
        cat.upsert(_entry(rng, int(f) - 1,
                          size=int(rng.integers(0, 2 ** 12)) * 1024,
                          atime=NOW - float(rng.integers(0, 10_000))))


def _random_grants(rng):
    """A spread of grant shapes: uid-only, gid-only, subtree-only, mixed."""
    g = GrantTable()
    g.add_subject(f"user{int(rng.integers(0, 4))}")
    g.add_subject("grp-aud", owners=(),
                  groups=(f"grp{int(rng.integers(0, 3))}",))
    trees = rng.choice(5, size=2, replace=False)
    g.add_subject("tree-aud", owners=(),
                  subtrees=tuple(f"/p/d{int(t)}" for t in trees))
    g.add_subject("mixed", owners=(f"user{int(rng.integers(0, 4))}",),
                  groups=(f"grp{int(rng.integers(0, 3))}",),
                  subtrees=(f"/p/d{int(rng.integers(0, 5))}",))
    g.add_subject("nobody", owners=("ghost-user",))   # matches nothing
    return g


class _Clock:
    def __init__(self, t=NOW):
        self.t = t

    def __call__(self):
        return self.t


FIND_CRITERIA = [
    "size > 2M",
    "size <= 1M and owner == 'user1'",
    "type == file and last_access > 1000s",
    "hsm_state == archived or size > 3M",
]

SUBJECTS = [None, "grp-aud", "tree-aud", "mixed", "nobody"]


def _pair(cat, clock, grants, mesh):
    """(store-backed, host-only oracle) Reports over the same catalog."""
    store = DeviceColumnStore(cat, mesh)
    pc_s = ProfileCube(cat, clock=clock).attach_device_store(store)
    pc_s.attach_grants(grants)
    r_s = Reports(cat, clock=clock, profiles=pc_s) \
        .attach_device_store(store).attach_grants(grants)
    pc_h = ProfileCube(cat, clock=clock)
    pc_h.attach_grants(grants)
    pc_h.rebuild(now=NOW)
    r_h = Reports(cat, clock=clock, profiles=pc_h).attach_grants(grants)
    return store, r_s, r_h


# -- store == scalar oracle, across churn rounds ------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_scoped_reports_differential_across_churn(seed):
    rng = np.random.default_rng(seed)
    cat = _random_catalog(rng, 400)
    clock = _Clock()
    grants = _random_grants(rng)
    store, r_s, r_h = _pair(cat, clock, grants, _shards_mesh())
    for round_ in range(3):
        for s in SUBJECTS:
            for crit in FIND_CRITERIA:
                assert r_s.find(crit, subject=s) \
                    == r_h.find(crit, subject=s), (s, crit)
            assert r_s.find("size > 1M", limit=5, subject=s) \
                == r_h.find("size > 1M", limit=5, subject=s)
            for p in ("/p/d0", "/p", "/nope"):
                assert r_s.du(p, subject=s) == r_h.du(p, subject=s), (s, p)
            assert r_s.du_many(["/p/d1", "/p/d3"], subject=s) \
                == r_h.du_many(["/p/d1", "/p/d3"], subject=s)
            for by in ("size", "atime"):
                for k in (1, 10):
                    assert r_s.top_files(by=by, k=k, subject=s) \
                        == r_h.top_files(by=by, k=k, subject=s), (s, by, k)
        _churn(cat, rng, 400, 40)
    assert r_s.last_fallback_reason is None
    assert r_s.host_served == 0 and r_s.store_served > 0


@pytest.mark.parametrize("seed", [0, 1])
def test_scoped_profile_reports_differential(seed):
    rng = np.random.default_rng(100 + seed)
    cat = _random_catalog(rng, 300)
    clock = _Clock()
    grants = _random_grants(rng)
    store, r_s, r_h = _pair(cat, clock, grants, _shards_mesh())
    for round_ in range(2):
        for s in SUBJECTS:
            assert r_s.report_user("user1", subject=s) \
                == r_h.report_user("user1", subject=s), s
            assert r_s.report_group("grp0", subject=s) \
                == r_h.report_group("grp0", subject=s), s
            assert r_s.report_types(subject=s) \
                == r_h.report_types(subject=s), s
            assert r_s.report_hsm(subject=s) == r_h.report_hsm(subject=s), s
            assert r_s.user_size_profile("user2", subject=s) \
                == r_h.user_size_profile("user2", subject=s), s
            assert r_s.age_profile(subject=s) \
                == r_h.age_profile(subject=s), s
            assert r_s.top_users(k=3, subject=s) \
                == r_h.top_users(k=3, subject=s), s
        _churn(cat, rng, 300, 30)
        r_h.profiles.rebuild(now=NOW)     # host oracle fold is not live


def test_unknown_subject_raises_not_falls_back():
    """An unknown subject is a caller error (KeyError), never a silent
    unscoped answer via the PolicyError fallback chain."""
    rng = np.random.default_rng(2)
    cat = _random_catalog(rng, 60)
    clock = _Clock()
    grants = _random_grants(rng)
    store, r_s, r_h = _pair(cat, clock, grants, _shards_mesh())
    for r in (r_s, r_h):
        with pytest.raises(KeyError, match="ghost"):
            r.find("size > 1M", subject="ghost")
        with pytest.raises(KeyError, match="ghost"):
            r.du("/p/d0", subject="ghost")
    assert r_s.last_fallback_reason is None


def test_scoped_glob_predicate_falls_back_scoped():
    """Host-only predicates still fall back — and the fallback itself is
    grant-filtered, not unscoped."""
    rng = np.random.default_rng(3)
    cat = _random_catalog(rng, 80)
    clock = _Clock()
    grants = _random_grants(rng)
    store, r_s, r_h = _pair(cat, clock, grants, _shards_mesh())
    out = r_s.find("name == 'f7'", subject="mixed")
    assert out == r_h.find("name == 'f7'", subject="mixed")
    assert r_s.last_fallback_reason is not None
    assert r_s.host_served == 1


def test_store_without_grants_rejects_subject():
    rng = np.random.default_rng(4)
    cat = _random_catalog(rng, 40)
    from repro.core import parse_expr
    store = DeviceColumnStore(cat, _shards_mesh())
    with pytest.raises(PolicyError, match="permissions plane"):
        store.match([parse_expr("size > 1M")], NOW, subject="anyone")
    r = Reports(cat, clock=_Clock())
    with pytest.raises(RuntimeError, match="attach_grants"):
        r.find("size > 1M", subject="anyone")


# -- bitmap maintenance: warm word scatter vs re-materialization --------------

def test_pure_update_churn_patches_bitmap_words():
    """Owner flips on existing rows reach the resident bitsets through the
    dirty-row word scatter — no full re-materialization."""
    rng = np.random.default_rng(5)
    cat = _random_catalog(rng, 240)
    clock = _Clock()
    grants = GrantTable()
    grants.add_subject("user1")
    store = DeviceColumnStore(cat, _shards_mesh())
    r_s = Reports(cat, clock=clock).attach_device_store(store) \
        .attach_grants(grants)
    r_h = Reports(cat, clock=clock).attach_grants(grants)
    assert r_s.find("size >= 0", subject="user1") \
        == r_h.find("size >= 0", subject="user1")
    mats = store.perm_materializations
    assert mats >= 1 and store.perm_word_scatters == 0
    # flip some rows' owner to/from user1: same fid+path => pure update
    for f in (3, 7, 11, 20):
        cat.upsert(_entry(rng, f - 1, owner="user1"))
    for f in (1, 5):
        cat.upsert(_entry(rng, f - 1, owner="user3"))
    assert r_s.find("size >= 0", subject="user1") \
        == r_h.find("size >= 0", subject="user1")
    assert store.perm_materializations == mats, \
        "pure-update churn forced a bitmap re-materialization"
    assert store.perm_word_scatters >= 1


def test_structural_churn_rematerializes_bitmap():
    """Inserting rows re-uploads the blocks; the permission plane must be
    rebuilt with them (it indexes catalog row ids)."""
    rng = np.random.default_rng(6)
    cat = _random_catalog(rng, 160)
    clock = _Clock()
    grants = GrantTable()
    grants.add_subject("tree", owners=(), subtrees=("/p/d2",))
    store = DeviceColumnStore(cat, _shards_mesh())
    r_s = Reports(cat, clock=clock).attach_device_store(store) \
        .attach_grants(grants)
    r_h = Reports(cat, clock=clock).attach_grants(grants)
    assert r_s.du("/p", subject="tree") == r_h.du("/p", subject="tree")
    mats = store.perm_materializations
    cat.upsert_batch([_entry(rng, i) for i in range(160, 200)])  # inserts
    assert r_s.du("/p", subject="tree") == r_h.du("/p", subject="tree")
    assert store.perm_materializations > mats
    assert r_s.last_fallback_reason is None


def test_grant_mutation_refreshes_bitmap():
    """GrantTable.grant bumps version; the next scoped query must serve
    the extended visibility, not the stale materialized bitset."""
    rng = np.random.default_rng(7)
    cat = _random_catalog(rng, 120)
    clock = _Clock()
    grants = GrantTable()
    grants.add_subject("aud", owners=(), groups=("grp0",))
    store = DeviceColumnStore(cat, _shards_mesh())
    r_s = Reports(cat, clock=clock).attach_device_store(store) \
        .attach_grants(grants)
    r_h = Reports(cat, clock=clock).attach_grants(grants)
    before = r_s.find("size >= 0", subject="aud")
    assert before == r_h.find("size >= 0", subject="aud")
    grants.grant("aud", subtrees=("/p/d4",))
    after = r_s.find("size >= 0", subject="aud")
    assert after == r_h.find("size >= 0", subject="aud")
    assert set(before) < set(after)          # strictly more visible rows
    # new subjects are also picked up (bitset row count grows)
    grants.add_subject("late", owners=("user2",))
    assert r_s.find("size >= 0", subject="late") \
        == r_h.find("size >= 0", subject="late")


# -- fallback-telemetry regressions (satellites 1 + 2) ------------------------

def test_fallback_reason_cleared_on_store_success():
    """A stale fallback reason must not outlive the next store-served
    query: fallback -> store-served -> reason is None again."""
    rng = np.random.default_rng(8)
    cat = _random_catalog(rng, 60)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r = Reports(cat, clock=clock).attach_device_store(store)
    r.find("name == 'f7'")                        # glob: host fallback
    assert r.last_fallback_reason is not None
    r.find("size > 1M")                           # store-served
    assert r.last_fallback_reason is None
    r.find("name == 'f9'")
    assert r.last_fallback_reason is not None
    assert r.du("/p/d0") == Reports(cat, clock=clock).du("/p/d0")
    assert r.last_fallback_reason is None         # du clears it too
    served, host = r.store_served, r.host_served
    r.reset_counters()
    assert (r.store_served, r.host_served, r.index_rebuilds) == (0, 0, 0)
    assert r.last_fallback_reason is None
    assert served == 2 and host == 2


def test_du_many_prefetches_indexes_once_on_fallback():
    """First mid-batch PolicyError switches the whole remainder to the
    host path with ONE index prefetch — not one rebuild pass per prefix."""
    rng = np.random.default_rng(9)
    cat = _random_catalog(rng, 80)
    clock = _Clock()

    calls = {"du": 0}

    class _AlwaysFalls:
        catalog = cat

        def du(self, p, subject=None):
            calls["du"] += 1
            raise PolicyError("injected")

    r = Reports(cat, clock=clock)
    r.device_store = _AlwaysFalls()
    prefixes = ["/p/d0", "/p/d1", "/p/d2", "/p/d4"]
    out = r.du_many(prefixes)
    assert out == Reports(cat, clock=clock).du_many(prefixes)
    assert calls["du"] == 1, "store retried after the first PolicyError"
    assert r.index_rebuilds == cat.n_shards, \
        f"expected one prefetch pass ({cat.n_shards} shard indexes), " \
        f"got {r.index_rebuilds}"
    assert r.host_served == len(prefixes)
    assert r.last_fallback_reason is not None


# -- multi-device ------------------------------------------------------------

def test_scoped_serving_on_eight_devices():
    out = run_subprocess("""
import numpy as np
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType,
                        GrantTable, HsmState)
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports
from repro.launch.mesh import make_shards_mesh

NOW = float(2 ** 20)
rng = np.random.default_rng(0)
cat = Catalog(n_shards=16)
cat.upsert_batch([Entry(
    fid=i + 1, name=f"f{i+1}", path=f"/p/d{i % 7}/f{i+1}",
    type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
    size=int(rng.integers(0, 2 ** 12)) * 1024,
    blocks=int(rng.integers(0, 2 ** 10)),
    owner=f"user{i % 5}", group=f"grp{i % 3}",
    hsm_state=HsmState(int(rng.integers(0, 5))),
    atime=NOW - float(rng.integers(0, 10_000)),
    mtime=NOW - float(rng.integers(0, 10_000))) for i in range(1200)])
g = GrantTable()
g.add_subject("user2")
g.add_subject("mixed", owners=("user4",), groups=("grp1",),
              subtrees=("/p/d5",))
clock = lambda: NOW
store = DeviceColumnStore(cat, make_shards_mesh())
assert store.n_devices == 8
pc = ProfileCube(cat, clock=clock).attach_device_store(store)
pc.attach_grants(g)
r_s = Reports(cat, clock=clock, profiles=pc) \\
    .attach_device_store(store).attach_grants(g)
pc_h = ProfileCube(cat, clock=clock)
pc_h.attach_grants(g)
pc_h.rebuild(now=NOW)
r_h = Reports(cat, clock=clock, profiles=pc_h).attach_grants(g)
for s in ("user2", "mixed"):
    assert r_s.find("size > 1M", subject=s) == r_h.find("size > 1M", subject=s)
    assert r_s.du("/p/d5", subject=s) == r_h.du("/p/d5", subject=s)
    assert r_s.top_files(k=9, subject=s) == r_h.top_files(k=9, subject=s)
    assert r_s.report_types(subject=s) == r_h.report_types(subject=s)
    assert r_s.top_users(k=4, subject=s) == r_h.top_users(k=4, subject=s)
assert r_s.host_served == 0 and r_s.last_fallback_reason is None
print("OK8")
""")
    assert "OK8" in out
