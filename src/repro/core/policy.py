"""Policy criteria language (C5): parse, evaluate, vectorize, compile.

The paper's example::

    (size > 1GB or owner == 'foo') and path == '/my/fs/*.tar'

Expressions support:

* numeric attributes with unit literals (``1GB``, ``30d``): ``size``,
  ``blocks``, ``nlink``, ``ost_idx``, ``archive_id``;
* age attributes (robinhood semantics — ``last_access > 30d`` means
  *accessed more than 30 days ago*): ``last_access``, ``last_mod``,
  ``creation``;
* string/categorical attributes: ``owner``, ``group``, ``pool``,
  ``status``, ``type`` (``file``/``dir``/``symlink``) with equality, and
  glob matching for ``path`` / ``name``;
* ``hsm_state`` (``none``/``dirty``/``archived``/``released``/...);
* boolean composition with ``and`` / ``or`` / ``not`` and parentheses.

Three evaluators, all oracle-equivalent (tested by hypothesis):

1. :meth:`Expr.evaluate` — per-entry Python (the paper's MySQL-row analogue);
2. :meth:`Expr.mask` — vectorized numpy over catalog columns;
3. :meth:`compile_program` — a flat postfix instruction program executed by
   the ``policy_scan`` Pallas TPU kernel (numeric/categorical predicates).
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import FsType, HsmState, parse_duration, parse_size

NUMERIC_ATTRS = ("size", "blocks", "nlink", "ost_idx", "archive_id", "mode",
                 "dirty")
AGE_ATTRS = {"last_access": "atime", "last_mod": "mtime", "creation": "ctime"}
CATEGORICAL_ATTRS = ("owner", "group", "pool", "status")
GLOB_ATTRS = ("path", "name")

_TYPE_NAMES = {"file": FsType.FILE, "dir": FsType.DIR,
               "directory": FsType.DIR, "symlink": FsType.SYMLINK,
               "other": FsType.OTHER}
_HSM_NAMES = {s.name.lower(): s for s in HsmState}

_OPS = ("==", "!=", ">=", "<=", ">", "<")

# Postfix program opcodes (shared with kernels/policy_scan).
OP_CMP_EQ, OP_CMP_NE, OP_CMP_GT, OP_CMP_GE, OP_CMP_LT, OP_CMP_LE = range(6)
OP_AND, OP_OR, OP_NOT = 6, 7, 8
OP_NOP = -1     # padding opcode: leaves the evaluation stack untouched
_CMP_CODE = {"==": OP_CMP_EQ, "!=": OP_CMP_NE, ">": OP_CMP_GT,
             ">=": OP_CMP_GE, "<": OP_CMP_LT, "<=": OP_CMP_LE}


class PolicyError(ValueError):
    pass


class Expr:
    """Base criteria node."""

    def evaluate(self, entry, now: float) -> bool:
        raise NotImplementedError

    def mask(self, cols: Dict[str, np.ndarray], strings, now: float) -> np.ndarray:
        raise NotImplementedError

    def to_postfix(self, strings, now: float) -> List[Tuple[int, int, float]]:
        """(opcode, col_index, operand) program; raises PolicyError on globs."""
        raise NotImplementedError


# Column order the kernel program indexes into (numeric/categorical subset).
KERNEL_COLUMNS = ("size", "blocks", "nlink", "ost_idx", "archive_id", "mode",
                  "dirty", "atime", "mtime", "ctime", "type", "hsm_state",
                  "owner", "group", "pool", "status")
_KCOL = {c: i for i, c in enumerate(KERNEL_COLUMNS)}


def _entry_attr(entry, attr: str):
    if isinstance(entry, dict):
        return entry[attr]
    return getattr(entry, attr)


@dataclass
class Cmp(Expr):
    attr: str
    op: str
    value: object          # int/float for numeric; str for cat/glob

    def __post_init__(self):
        if self.op not in _OPS:
            raise PolicyError(f"bad operator {self.op!r}")

    # -- scalar ---------------------------------------------------------------
    def _cmp(self, lhs, rhs) -> bool:
        return {"==": lhs == rhs, "!=": lhs != rhs, ">": lhs > rhs,
                ">=": lhs >= rhs, "<": lhs < rhs, "<=": lhs <= rhs}[self.op]

    def evaluate(self, entry, now: float) -> bool:
        a = self.attr
        if a in NUMERIC_ATTRS:
            return self._cmp(int(_entry_attr(entry, a)), self.value)
        if a in AGE_ATTRS:
            age = now - float(_entry_attr(entry, AGE_ATTRS[a]))
            return self._cmp(age, self.value)
        if a == "type":
            tv = _entry_attr(entry, "type")
            tv = int(tv) if not isinstance(tv, str) else int(_TYPE_NAMES[tv])
            return self._cmp(tv, int(self.value))
        if a == "hsm_state":
            return self._cmp(int(_entry_attr(entry, a)), int(self.value))
        if a in CATEGORICAL_ATTRS:
            if self.op not in ("==", "!="):
                raise PolicyError(f"{a} supports ==/!= only")
            return self._cmp(str(_entry_attr(entry, a)), self.value)
        if a in GLOB_ATTRS:
            if self.op not in ("==", "!="):
                raise PolicyError(f"{a} supports ==/!= only")
            hit = fnmatch.fnmatchcase(str(_entry_attr(entry, a)), self.value)
            return hit if self.op == "==" else not hit
        raise PolicyError(f"unknown attribute {a!r}")

    # -- vectorized -------------------------------------------------------------
    def _npcmp(self, lhs: np.ndarray, rhs) -> np.ndarray:
        return {"==": lhs == rhs, "!=": lhs != rhs, ">": lhs > rhs,
                ">=": lhs >= rhs, "<": lhs < rhs, "<=": lhs <= rhs}[self.op]

    def mask(self, cols, strings, now: float) -> np.ndarray:
        a = self.attr
        if a in NUMERIC_ATTRS:
            return self._npcmp(cols[a], self.value)
        if a in AGE_ATTRS:
            return self._npcmp(now - cols[AGE_ATTRS[a]], self.value)
        if a in ("type", "hsm_state"):
            return self._npcmp(cols[a], int(self.value))
        if a in CATEGORICAL_ATTRS:
            code = strings.code_of(self.value)
            if code is None:          # string never interned -> no entry has it
                n = len(cols[a])
                return np.zeros(n, bool) if self.op == "==" else np.ones(n, bool)
            return self._npcmp(cols[a], code)
        if a in GLOB_ATTRS:
            pat = re.compile(fnmatch.translate(self.value))
            key = "_paths" if a == "path" else "_names"
            hit = np.fromiter((pat.match(s) is not None for s in cols[key]),
                              dtype=bool, count=len(cols[key]))
            return hit if self.op == "==" else ~hit
        raise PolicyError(f"unknown attribute {a!r}")

    # -- kernel program -----------------------------------------------------------
    def to_postfix(self, strings, now: float):
        a = self.attr
        op = _CMP_CODE[self.op]
        if a in NUMERIC_ATTRS:
            return [(op, _KCOL[a], float(self.value))]
        if a in AGE_ATTRS:
            # age > T  <=>  time_col < now - T  (flip the comparison)
            flip = {OP_CMP_GT: OP_CMP_LT, OP_CMP_GE: OP_CMP_LE,
                    OP_CMP_LT: OP_CMP_GT, OP_CMP_LE: OP_CMP_GE,
                    OP_CMP_EQ: OP_CMP_EQ, OP_CMP_NE: OP_CMP_NE}[op]
            return [(flip, _KCOL[AGE_ATTRS[a]], float(now - self.value))]
        if a in ("type", "hsm_state"):
            return [(op, _KCOL[a], float(int(self.value)))]
        if a in CATEGORICAL_ATTRS:
            code = strings.code_of(self.value)
            code = -1.0 if code is None else float(code)
            return [(op, _KCOL[a], code)]
        raise PolicyError(f"attribute {a!r} not supported by the kernel path "
                          "(glob predicates run on the host)")


@dataclass
class And(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, entry, now):
        return self.lhs.evaluate(entry, now) and self.rhs.evaluate(entry, now)

    def mask(self, cols, strings, now):
        return self.lhs.mask(cols, strings, now) & self.rhs.mask(cols, strings, now)

    def to_postfix(self, strings, now):
        return self.lhs.to_postfix(strings, now) + self.rhs.to_postfix(strings, now) \
            + [(OP_AND, 0, 0.0)]


@dataclass
class Or(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, entry, now):
        return self.lhs.evaluate(entry, now) or self.rhs.evaluate(entry, now)

    def mask(self, cols, strings, now):
        return self.lhs.mask(cols, strings, now) | self.rhs.mask(cols, strings, now)

    def to_postfix(self, strings, now):
        return self.lhs.to_postfix(strings, now) + self.rhs.to_postfix(strings, now) \
            + [(OP_OR, 0, 0.0)]


@dataclass
class Not(Expr):
    inner: Expr

    def evaluate(self, entry, now):
        return not self.inner.evaluate(entry, now)

    def mask(self, cols, strings, now):
        return ~self.inner.mask(cols, strings, now)

    def to_postfix(self, strings, now):
        return self.inner.to_postfix(strings, now) + [(OP_NOT, 0, 0.0)]


@dataclass
class Const(Expr):
    value: bool

    def evaluate(self, entry, now):
        return self.value

    def mask(self, cols, strings, now):
        return np.full(len(cols["fid"]), self.value, dtype=bool)

    def to_postfix(self, strings, now):
        # encode as tautology / contradiction on the size column
        op = OP_CMP_GE if self.value else OP_CMP_LT
        return [(op, _KCOL["size"], float("-inf"))]


ALWAYS = Const(True)

# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------

_TOKEN = re.compile(
    r"""\s*(?:
        (?P<lpar>\() | (?P<rpar>\)) |
        (?P<op>==|!=|>=|<=|>|<) |
        (?P<str>'[^']*'|"[^"]*") |
        (?P<word>[A-Za-z0-9_./*?\[\]\-~+]+)
    )""", re.VERBOSE)


def _tokenize(text: str) -> List[Tuple[str, str]]:
    out, pos = [], 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if m is None:
            if text[pos:].strip() == "":
                break
            raise PolicyError(f"cannot tokenize near {text[pos:pos+20]!r}")
        pos = m.end()
        for kind in ("lpar", "rpar", "op", "str", "word"):
            v = m.group(kind)
            if v is not None:
                out.append((kind, v))
                break
    return out


_SIZE_RE = re.compile(r"^\d+(\.\d+)?\s*[KMGTP]?B?$", re.IGNORECASE)
_DUR_RE = re.compile(r"^\d+(\.\d+)?(s|sec|m|min|h|d|w|y)$", re.IGNORECASE)
_NUM_RE = re.compile(r"^-?\d+(\.\d+)?$")


def _parse_value(attr: str, tok_kind: str, tok: str):
    raw = tok[1:-1] if tok_kind == "str" else tok
    if attr in AGE_ATTRS:
        return parse_duration(raw)
    if attr == "type":
        return int(_TYPE_NAMES[raw.lower()])
    if attr == "hsm_state":
        return int(_HSM_NAMES[raw.lower()])
    if attr in NUMERIC_ATTRS:
        if _NUM_RE.match(raw):
            return int(float(raw))
        if _SIZE_RE.match(raw):
            return parse_size(raw)
        raise PolicyError(f"bad numeric literal {raw!r} for {attr}")
    return raw   # categorical / glob keeps the string


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def peek(self):
        return self.toks[self.i] if self.i < len(self.toks) else (None, None)

    def next(self):
        t = self.peek()
        self.i += 1
        return t

    def parse(self) -> Expr:
        e = self.or_expr()
        if self.i != len(self.toks):
            raise PolicyError(f"trailing tokens: {self.toks[self.i:]}")
        return e

    def or_expr(self) -> Expr:
        e = self.and_expr()
        while self.peek() == ("word", "or"):
            self.next()
            e = Or(e, self.and_expr())
        return e

    def and_expr(self) -> Expr:
        e = self.not_expr()
        while self.peek() == ("word", "and"):
            self.next()
            e = And(e, self.not_expr())
        return e

    def not_expr(self) -> Expr:
        kind, val = self.peek()
        if (kind, val) == ("word", "not"):
            self.next()
            return Not(self.not_expr())
        if kind == "lpar":
            self.next()
            e = self.or_expr()
            k, _ = self.next()
            if k != "rpar":
                raise PolicyError("missing ')'")
            return e
        if (kind, val) == ("word", "true"):
            self.next()
            return Const(True)
        if (kind, val) == ("word", "false"):
            self.next()
            return Const(False)
        return self.cmp()

    def cmp(self) -> Expr:
        kind, attr = self.next()
        if kind != "word":
            raise PolicyError(f"expected attribute, got {attr!r}")
        kind, op = self.next()
        if kind != "op":
            raise PolicyError(f"expected operator after {attr!r}, got {op!r}")
        vkind, vtok = self.next()
        if vkind not in ("word", "str"):
            raise PolicyError(f"expected value, got {vtok!r}")
        return Cmp(attr, op, _parse_value(attr, vkind, vtok))


def parse_expr(text: str) -> Expr:
    """Parse a criteria expression string into an AST."""
    return _Parser(_tokenize(text)).parse()


def compile_program(expr: Expr, strings, now: float
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten an AST into kernel instruction arrays (opcode, col, operand)."""
    prog = expr.to_postfix(strings, now)
    ops = np.array([p[0] for p in prog], dtype=np.int32)
    cols = np.array([p[1] for p in prog], dtype=np.int32)
    operands = np.array([p[2] for p in prog], dtype=np.float32)
    return ops, cols, operands


def iter_exprs(expr: Expr):
    """Yield every node of a criteria AST (pre-order)."""
    yield expr
    if isinstance(expr, (And, Or)):
        yield from iter_exprs(expr.lhs)
        yield from iter_exprs(expr.rhs)
    elif isinstance(expr, Not):
        yield from iter_exprs(expr.inner)


def any_of(exprs: Sequence[Expr]) -> Expr:
    """OR-fold a list of criteria (empty list -> ALWAYS)."""
    if not exprs:
        return ALWAYS
    out = exprs[0]
    for e in exprs[1:]:
        out = Or(out, e)
    return out


def all_of(exprs: Sequence[Expr]) -> Expr:
    """AND-fold a list of criteria (empty list -> ALWAYS)."""
    if not exprs:
        return ALWAYS
    out = exprs[0]
    for e in exprs[1:]:
        out = And(out, e)
    return out


def attribute_rules(rule_masks: Sequence[np.ndarray], n: int) -> np.ndarray:
    """First-match-wins rule attribution: the single host-side authority.

    ``rule_masks`` are the per-rule boolean masks in priority order (the
    policy's combined criteria mask is NOT included). Returns (n,) int32:
    index of the first matching rule per row, -1 where none match. The
    engine's numpy path, the per-rule-launch kernel fallback, and the
    fused on-device attribution (``attribute_ref`` / the batch kernel) all
    implement exactly these semantics — differential-tested equal.
    """
    if not rule_masks:
        return np.full(n, -1, dtype=np.int32)
    stacked = np.stack(rule_masks)
    idx = np.argmax(stacked, axis=0).astype(np.int32)   # first True wins
    idx[~stacked.any(axis=0)] = -1
    return idx


def compile_programs(exprs: Sequence[Expr], strings, now: float
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compile several criteria into one (R, P) instruction batch.

    Programs are right-padded with OP_NOP so a single vmapped scan can
    evaluate all of them over the same column stack in one pass.
    """
    progs = [e.to_postfix(strings, now) for e in exprs]
    plen = max(len(p) for p in progs)
    ops = np.full((len(progs), plen), OP_NOP, dtype=np.int32)
    cols = np.zeros((len(progs), plen), dtype=np.int32)
    operands = np.zeros((len(progs), plen), dtype=np.float32)
    for r, prog in enumerate(progs):
        for i, (op, col, val) in enumerate(prog):
            ops[r, i] = op
            cols[r, i] = col
            operands[r, i] = val
    return ops, cols, operands
