"""Paper SII-B4: rbh-find / rbh-du clones vs POSIX walking, on a REAL
directory tree (PosixFs backend)."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core import Catalog, Reports, Scanner, StatsAggregator
from repro.fs import PosixFs


def _make_tree(root, n_dirs=40, files_per_dir=25):
    rng = __import__("random").Random(0)
    dirs = [root]
    for i in range(n_dirs):
        d = os.path.join(rng.choice(dirs[-10:]), f"d{i}")
        os.makedirs(d, exist_ok=True)
        dirs.append(d)
        for j in range(files_per_dir):
            with open(os.path.join(d, f"f{j}.dat"), "wb") as f:
                f.write(b"x" * rng.randint(0, 4096))


def run() -> list:
    rows = []
    tmp = tempfile.mkdtemp(prefix="rbh_bench_")
    try:
        _make_tree(tmp)
        fs = PosixFs(tmp)
        cat = Catalog()
        stats = StatsAggregator(cat.strings)
        cat.add_delta_hook(stats.on_delta)
        t0 = time.perf_counter()
        st = Scanner(fs, cat, n_threads=4).scan()
        scan_dt = time.perf_counter() - t0
        rows.append(("posix_initial_scan", 1e6 * scan_dt / st.entries,
                     f"{st.entries}_entries"))
        rep = Reports(cat, stats)

        # find: files > 2KB
        t0 = time.perf_counter()
        hits_posix = []
        for dirpath, _d, files in os.walk(tmp):
            for f in files:
                p = os.path.join(dirpath, f)
                if os.path.getsize(p) > 2048:
                    hits_posix.append(p)
        dt_posix = time.perf_counter() - t0
        t0 = time.perf_counter()
        hits_db = rep.find("type == file and size > 2k")
        dt_db = time.perf_counter() - t0
        assert len(hits_db) == len(hits_posix)
        rows.append(("find_posix_walk", 1e6 * dt_posix,
                     f"{len(hits_posix)}_hits"))
        rows.append(("find_rbh_db", 1e6 * dt_db,
                     f"speedup_{dt_posix/max(dt_db,1e-9):.1f}x"))

        # du -s
        t0 = time.perf_counter()
        total = 0
        for dirpath, _d, files in os.walk(tmp):
            for f in files:
                total += os.path.getsize(os.path.join(dirpath, f))
        dt_posix_du = time.perf_counter() - t0
        t0 = time.perf_counter()
        du = rep.du(tmp)
        dt_db_du = time.perf_counter() - t0
        assert du["volume"] == total
        rows.append(("du_posix_walk", 1e6 * dt_posix_du, f"{total}_bytes"))
        rows.append(("du_rbh_db", 1e6 * dt_db_du,
                     f"speedup_{dt_posix_du/max(dt_db_du,1e-9):.1f}x"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows
