import numpy as np
import pytest

from repro.core import Catalog, Entry, FsType, HsmState


def _entry(fid, **kw):
    defaults = dict(parent_fid=1, name=f"f{fid}", path=f"/a/f{fid}",
                    type=FsType.FILE, size=fid * 100, blocks=fid * 100,
                    owner="foo", atime=1.0, mtime=1.0, ctime=1.0)
    defaults.update(kw)
    return Entry(fid=fid, **defaults)


def test_upsert_get_roundtrip():
    cat = Catalog(n_shards=3)
    e = _entry(42, owner="bar", pool="ssd", hsm_state=HsmState.ARCHIVED,
               xattrs={"k": "v"}, stripe_osts=(1, 2))
    cat.upsert(e)
    out = cat.get(42)
    assert out.owner == "bar" and out.pool == "ssd"
    assert out.hsm_state == HsmState.ARCHIVED
    assert out.xattrs == {"k": "v"} and out.stripe_osts == (1, 2)
    assert len(cat) == 1


def test_update_fields_and_remove():
    cat = Catalog(n_shards=2)
    cat.upsert(_entry(7))
    assert cat.update_fields(7, size=999, owner="baz")
    assert cat.get(7).size == 999 and cat.get(7).owner == "baz"
    assert cat.remove(7)
    assert cat.get(7) is None
    assert not cat.remove(7)


def test_vector_query():
    cat = Catalog(n_shards=4)
    for i in range(1, 101):
        cat.upsert(_entry(i, owner="foo" if i % 2 else "bar"))
    fids = cat.query_fids(lambda c: c["size"] > 5000)
    assert sorted(fids.tolist()) == list(range(51, 101))
    cols = cat.arrays()
    assert len(cols["_paths"]) == 100


def test_sqlite_persistence_roundtrip(tmp_path):
    db = str(tmp_path / "cat.db")
    cat = Catalog(n_shards=2, db_path=db)
    for i in range(1, 21):
        cat.upsert(_entry(i))
    cat.remove(5)
    # crash: new catalog from same file
    cat2 = Catalog(n_shards=2, db_path=db)
    n = cat2.load_from_db()
    assert n == 19
    assert cat2.get(5) is None and cat2.get(6).size == 600


def test_delta_hooks_fire():
    cat = Catalog(n_shards=1)
    deltas = []
    cat.add_delta_hook(lambda old, new: deltas.append((old, new)))
    cat.upsert(_entry(1))
    cat.update_fields(1, size=5)
    cat.remove(1)
    assert len(deltas) == 3
    assert deltas[0][0] is None and deltas[2][1] is None
