"""whisper-large-v3 [audio]: enc-dec 32L each, d_model=1280 20H (MHA)
d_ff=5120 vocab=51866 — conv frontend STUB (input_specs provides frame
embeddings (B, 1500, 1280)). LayerNorm + gelu MLP. [arXiv:2212.04356]
"""
from repro.models.config import (ATTN_FULL, EncoderSpec, LayerSpec,
                                 ModelConfig)

_PATTERN = (LayerSpec(mix=ATTN_FULL, cross_attn=True),)

CONFIG = ModelConfig(
    name="whisper_large_v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, head_dim=64,
    d_ff=5120, vocab=51866,
    pattern=_PATTERN, norm="ln", ffn_act="gelu", qkv_bias=True,
    encoder=EncoderSpec(n_layers=32, n_frames=1500),
    max_position=32768, norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name="whisper_smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN, norm="ln", ffn_act="gelu", qkv_bias=True,
    encoder=EncoderSpec(n_layers=2, n_frames=16),
    max_position=128, norm_eps=1e-5,
)
