"""Unified telemetry plane: registry, spans, export, fallback telemetry.

Covers the cross-cutting contracts the per-component suites don't:
the registry backing every pre-existing counter, the run span tree,
Prometheus round-trip, the scrape-boundary reset clearing all counter
families together, and each documented evaluator downgrade recorded as a
``fallback{stage=,reason=}`` counter matching ``RunReport`` /
``Reports`` string telemetry.
"""
import threading

import pytest

from repro.core import (AlertManager, AlertRule, Catalog, EventPipeline,
                        MetricRegistry, PipelineConfig, PolicyDefinition,
                        PolicyEngine, Reports, Scanner, StatsAggregator,
                        parse_prometheus)
from repro.core.telemetry import slug, span
from repro.fs import LustreSim


def _fs(n_files: int = 30):
    fs = LustreSim(n_osts=4)
    proj = fs.mkdir(fs.root_fid(), "proj")
    for i in range(n_files):
        f = fs.create(proj, f"data{i}.bin", owner=f"u{i % 3}")
        fs.write(f, (i + 1) * 100)
    return fs, proj


# -- registry ------------------------------------------------------------------
def test_counter_gauge_histogram_families():
    reg = MetricRegistry()
    reg.counter("events", kind="a").inc(3)
    reg.counter("events", kind="b").inc()
    reg.gauge("depth").set(7.5)
    h = reg.histogram("lat", edges=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["events"]["series"]["kind=a"] == 3
    assert snap["events"]["series"]["kind=b"] == 1
    assert snap["depth"]["series"][""] == 7.5
    hs = snap["lat"]["series"][""]
    assert hs["count"] == 4 and hs["counts"] == [1, 2, 1, 0]
    assert 0.01 <= hs["p50"] <= 0.1


def test_histogram_memory_is_bounded_and_percentile_sane():
    reg = MetricRegistry()
    h = reg.histogram("h", edges=(1.0, 2.0, 4.0))
    for i in range(10_000):
        h.observe(float(i % 5))
    assert len(h.counts) == 4        # fixed buckets, not 10k samples
    assert 1.0 <= h.percentile(0.5) <= 4.0


def test_same_name_different_kind_rejected():
    reg = MetricRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_disabled_registry_is_noop_but_readable():
    reg = MetricRegistry(enabled=False)
    reg.counter("c").inc(5)
    reg.histogram("h").observe(1.0)
    with reg.trace("t"):
        pass
    assert reg.counter("c").value == 0
    assert reg.histogram("h").count == 0
    assert reg.spans() == []


def test_prometheus_roundtrip_and_escaping():
    reg = MetricRegistry()
    reg.counter("ops", help="ops done", stage='we"ird\nname').inc(2)
    reg.gauge("depth", mdt="0").set(3)
    reg.histogram("lat", edges=(0.1, 1.0)).observe(0.5)
    reg.state("why").set("policy_scan->numpy: glob")
    text = reg.render_prometheus()
    parsed = parse_prometheus(text)          # raises on malformed lines
    assert any(k.startswith("ops") for k in parsed)
    assert parsed['lat_bucket{le="+Inf"}'] == 1
    assert parsed['lat_count'] == 1
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line at all }{")


def test_callback_gauges_read_live_state():
    reg = MetricRegistry()
    depth = {"v": 1}
    reg.register_callback("queue_depth",
                          lambda: [({"q": "main"}, depth["v"])])
    assert reg.snapshot()["queue_depth"]["series"]["q=main"] == 1
    depth["v"] = 9
    assert reg.snapshot()["queue_depth"]["series"]["q=main"] == 9
    assert parse_prometheus(reg.render_prometheus())[
        'queue_depth{q="main"}'] == 9


def test_trace_nesting_and_threads():
    reg = MetricRegistry()
    with reg.trace("outer") as sp:
        with reg.trace("inner"):
            pass
        sp.annotate(tag=1)

    def worker():
        with reg.trace("thread_root"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    roots = reg.spans()
    names = [s.name for s in roots]
    assert "outer" in names and "thread_root" in names
    outer = reg.spans("outer")[0]
    assert [c.name for c in outer.children] == ["inner"]
    assert outer.elapsed >= outer.children[0].elapsed
    # every close also feeds span_seconds{span=}
    assert reg.snapshot()["span_seconds"]["series"]["span=inner"]["count"] == 1


def test_ambient_span_is_noop_outside_trace():
    with span("orphan") as sp:          # no active trace: shared no-op
        sp.annotate(ignored=True)
    reg = MetricRegistry()
    with reg.trace("root"):
        with span("child", idx=1):
            pass
    assert [c.name for c in reg.spans("root")[0].children] == ["child"]


def test_slug_bounds_label_cardinality():
    s = slug("policy_scan_mesh->policy_scan: no device store " * 20)
    assert len(s) <= 60 and s == slug(s)  # idempotent, bounded, sanitized


# -- component wiring ----------------------------------------------------------
def test_one_registry_backs_all_component_counters():
    fs, _ = _fs()
    cat = Catalog()
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    Scanner(fs, cat).scan()
    rep = Reports(cat, stats)
    cat.arrays()
    rep.du("/proj")
    rep.find("size > 1000")
    values = cat.telemetry.counter_values()
    assert values['catalog_arrays_calls{catalog="catalog0"}'] \
        == cat.arrays_calls
    assert values['reports_host_served{reports="reports0"}'] \
        == rep.host_served == 2
    assert values['reports_index_rebuilds{reports="reports0"}'] \
        == rep.index_rebuilds


def test_injected_shared_registry_instance_labels():
    reg = MetricRegistry()
    a, b = Catalog(telemetry=reg), Catalog(telemetry=reg)
    a.arrays()
    a.arrays()
    b.arrays()
    assert a.arrays_calls == 2 and b.arrays_calls == 1
    vals = reg.counter_values()
    assert vals['catalog_arrays_calls{catalog="catalog0"}'] == 2
    assert vals['catalog_arrays_calls{catalog="catalog1"}'] == 1


def test_pipeline_and_stream_telemetry():
    fs = LustreSim(n_mdts=1)
    d = fs.mkdir(fs.root_fid(), "dir")
    cat = Catalog()
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig())
    assert stream.telemetry is cat.telemetry
    for i in range(10):
        f = fs.create(d, f"f{i}", owner="u", uid="u")
        fs.write(f, 100)
    assert stream.backlog() > 0
    pipe.process_once(100000)
    assert stream.backlog() == 0
    assert stream.lag_seconds() == 0.0
    vals = cat.telemetry.counter_values()
    assert vals['changelog_events_emitted{mdt="0"}'] >= 20   # 10x(create+write)
    assert vals['pipeline_records_processed{pipeline="pipeline0"}'] \
        == pipe.processed > 0
    snap = cat.telemetry.snapshot()
    series = snap["changelog_backlog_mdt0"]["series"]
    assert series and all(v == 0 for v in series.values())


# -- scrape-boundary reset (satellite: reset clears ALL families) --------------
def test_reset_counters_clears_every_family_together():
    fs, _ = _fs()
    cat = Catalog()
    Scanner(fs, cat).scan()
    rep = Reports(cat)
    rep.du("/proj")
    rep.find("path == '/proj/*.bin'")      # glob: host fold
    assert rep.host_served == 2 and rep.index_rebuilds > 0
    assert cat.arrays_calls > 0
    # a fallback leaves both the string state and the counter family
    rep.last_fallback_reason = "find: synthetic"
    vals = cat.telemetry.counter_values()
    assert any(v for v in vals.values())
    rep.reset_counters()
    assert (rep.store_served, rep.host_served, rep.index_rebuilds) \
        == (0, 0, 0)
    assert rep.last_fallback_reason is None
    assert cat.arrays_calls == 0           # same registry, same boundary
    assert all(v == 0 for v in cat.telemetry.counter_values().values())
    hists = [f for f in cat.telemetry.snapshot().values()
             if f["kind"] == "histogram"]
    assert all(s["count"] == 0 for f in hists for s in f["series"].values())


# -- fallback chain as telemetry (satellite: no silent downgrades) -------------
def _engine(fs, cat, evaluator):
    Scanner(fs, cat).scan()
    eng = PolicyEngine(cat, clock=lambda: 2e9)
    hits = []
    pd = PolicyDefinition.from_config(
        "p", lambda e, params: hits.append(e) or True,
        scope="path == '/proj/*.bin'",   # glob: kernel paths must degrade
        evaluator=evaluator, mutates=False, dry_run=True)
    eng.register(pd)
    return eng


def _fallback_series(reg):
    out = {}
    for name, value in reg.counter_values().items():
        if name.startswith("fallback{"):
            out[name] = value
    return out


def test_fallback_chain_mesh_to_policy_scan_to_numpy():
    fs, _ = _fs()
    cat = Catalog()
    # no device store attached: policy_scan_mesh must degrade to
    # policy_scan, whose glob predicate then degrades to numpy — BOTH
    # edges must land in the registry and match the RunReport string
    eng = _engine(fs, cat, "policy_scan_mesh")
    rep = eng.run("p", matching="full")
    assert rep.evaluator == "numpy"
    assert "policy_scan_mesh->policy_scan" in rep.fallback_reason
    assert "policy_scan->numpy" in rep.fallback_reason
    series = _fallback_series(cat.telemetry)
    stages = [k for k in series]
    assert any('stage="policy_scan_mesh->policy_scan"' in k
               for k in stages), stages
    assert any('stage="policy_scan->numpy"' in k for k in stages), stages
    assert sum(series.values()) == 2
    # the same deltas ride on the run's own telemetry
    run_counters = rep.telemetry["counters"]
    assert sum(v for k, v in run_counters.items()
               if k.startswith("fallback{")) == 2


def test_fallback_policy_scan_to_numpy_only():
    fs, _ = _fs()
    cat = Catalog()
    eng = _engine(fs, cat, "policy_scan")
    rep = eng.run("p", matching="full")
    assert rep.evaluator == "numpy"
    assert rep.fallback_reason.startswith("policy_scan->numpy")
    series = _fallback_series(cat.telemetry)
    assert len(series) == 1 and sum(series.values()) == 1
    assert 'stage="policy_scan->numpy"' in next(iter(series))


def test_no_fallback_records_nothing():
    fs, _ = _fs()
    cat = Catalog()
    eng = _engine(fs, cat, "numpy")
    rep = eng.run("p", matching="full")
    assert rep.fallback_reason == ""
    assert _fallback_series(cat.telemetry) == {}


def test_reports_fallback_counter_matches_string():
    fs, _ = _fs()
    cat = Catalog()
    Scanner(fs, cat).scan()
    rep = Reports(cat)
    rep.find("path == '/proj/*.bin'")
    # no store attached: host path, no fallback counter (nothing degraded)
    assert _fallback_series(cat.telemetry) == {}
    assert rep.last_fallback_reason is None


# -- run span tree -------------------------------------------------------------
def test_run_report_carries_span_tree_and_counter_deltas():
    fs, _ = _fs()
    cat = Catalog()
    eng = _engine(fs, cat, "numpy")
    rep = eng.run("p", matching="full")
    tree = rep.telemetry["spans"]
    assert tree["name"] == "run"
    child_names = [c["name"] for c in tree["children"]]
    assert child_names[:2] == ["run.ingest", "run.match"]
    assert "run.act" in child_names
    assert tree["elapsed_s"] >= 0
    # deltas only contain series this run actually moved
    assert all(v != 0 for v in rep.telemetry["counters"].values())
    # disabled registry: no per-run telemetry, run still works
    cat.telemetry.enabled = False
    rep2 = eng.run("p", matching="full")
    assert rep2.telemetry == {}


# -- alerts (satellite: persistent handle + alerts_fired) ----------------------
def test_alert_log_persistent_handle_and_counter(tmp_path):
    fs, proj = _fs(5)
    cat = Catalog()
    log = tmp_path / "alerts.log"
    with AlertManager(str(log), telemetry=cat.telemetry) as mgr:
        mgr.add_rule(AlertRule("big", "size > 250"))
        cat.add_entry_hook(mgr.on_entry)
        Scanner(fs, cat).scan()
        assert mgr._fh is not None          # lazy-opened once, kept open
        fired = len(mgr.fired)
        assert fired > 0
        lines = log.read_text().strip().splitlines()
        assert len(lines) == fired          # flushed per record
    assert mgr._fh is None                  # context manager closed it
    vals = cat.telemetry.counter_values()
    assert vals['alerts_fired{rule="big"}'] == fired
    # firing after close lazily reopens
    f = fs.create(proj, "huge.bin", owner="u0")
    fs.write(f, 10_000)
    Scanner(fs, cat).scan()
    assert len(mgr.fired) > fired
    mgr.close()
