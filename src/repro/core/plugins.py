"""Shipped policy plugins (C10 — robinhood v3 architecture, Fig. 4).

Each plugin is an action factory: given runtime handles it returns an
``Action`` callable usable in a :class:`PolicyDefinition`. Administrators
compose policies from these "with a few lines of configuration"; custom
plugins are just new callables registered in :data:`PLUGIN_REGISTRY`.

Actions may additionally expose a **batch interface** by attaching an
``action_batch(entries, params) -> list[bool]`` attribute to the callable:
the batched policy engine then applies whole chunks at once (one catalog
commit per chunk instead of one per entry).
"""
from __future__ import annotations

import os
import shutil
from typing import Callable, Dict, List

from .catalog import Catalog
from .types import Entry, HsmState

PluginFactory = Callable[..., Callable[[Entry, dict], bool]]
PLUGIN_REGISTRY: Dict[str, PluginFactory] = {}


def register_plugin(name: str) -> Callable[[PluginFactory], PluginFactory]:
    def deco(fn: PluginFactory) -> PluginFactory:
        PLUGIN_REGISTRY[name] = fn
        return fn
    return deco


@register_plugin("purge")
def purge_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Delete entries (classic cleanup policy)."""

    def action(e: Entry, params: dict) -> bool:
        fs.unlink(e.fid)
        catalog.remove(e.fid)
        return True

    def action_batch(entries: List[Entry], params: dict) -> List[bool]:
        oks = []
        for e in entries:
            try:
                fs.unlink(e.fid)
                oks.append(True)
            except Exception:
                oks.append(False)
        catalog.remove_batch([e.fid for e, ok in zip(entries, oks) if ok])
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("rmdir_empty")
def rmdir_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Remove old empty directories."""

    def action(e: Entry, params: dict) -> bool:
        if fs.readdir(e.fid):
            return False
        fs.unlink(e.fid)
        catalog.remove(e.fid)
        return True

    return action


@register_plugin("archive")
def archive_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    def action(e: Entry, params: dict) -> bool:
        fs.hsm_archive(e.fid, archive_id=params.get("archive_id", 1))
        catalog.update_fields(e.fid, hsm_state=HsmState.ARCHIVED)
        return True

    def action_batch(entries: List[Entry], params: dict) -> List[bool]:
        archive_id = params.get("archive_id", 1)
        oks = []
        for e in entries:
            try:
                fs.hsm_archive(e.fid, archive_id=archive_id)
                oks.append(True)
            except Exception:
                oks.append(False)
        catalog.update_fields_batch(
            [e.fid for e, ok in zip(entries, oks) if ok],
            hsm_state=HsmState.ARCHIVED)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("release")
def release_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    def action(e: Entry, params: dict) -> bool:
        fs.hsm_release(e.fid)
        catalog.update_fields(e.fid, hsm_state=HsmState.RELEASED, blocks=0)
        return True

    def action_batch(entries: List[Entry], params: dict) -> List[bool]:
        oks = []
        for e in entries:
            try:
                fs.hsm_release(e.fid)
                oks.append(True)
            except Exception:
                oks.append(False)
        catalog.update_fields_batch(
            [e.fid for e, ok in zip(entries, oks) if ok],
            hsm_state=HsmState.RELEASED, blocks=0)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("migrate_pool")
def migrate_pool_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Internal data migration between OST pools (paper SIII-D: SSD<->HDD).

    Re-stripes a file's data onto the target pool's OSTs (simulated move)
    and updates pool/ost metadata — the 'data must be moved between pools of
    storage resources according to site-specific policies' case.
    """

    def action(e: Entry, params: dict) -> bool:
        target_pool = params.get("pool", "")
        cands = fs.pools.get(target_pool)
        if not cands:
            return False
        node = fs._nodes.get(e.fid)
        if node is None:
            return False
        with fs._lock:
            per = node.data_len // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
            for idx in e.stripe_osts:
                fs.osts[idx].free(per)
            n = min(fs.stripe_count, len(cands))
            new_stripes = tuple(cands[i % len(cands)] for i in range(n))
            per_new = node.data_len // max(1, len(new_stripes))
            for idx in new_stripes:
                fs.osts[idx].alloc(per_new)
            node.entry.stripe_osts = new_stripes
            node.entry.ost_idx = new_stripes[0] if new_stripes else -1
            node.entry.pool = target_pool
        catalog.update_fields(e.fid, pool=target_pool,
                              ost_idx=new_stripes[0] if new_stripes else -1,
                              stripe_osts=new_stripes)
        return True

    return action


@register_plugin("checksum")
def checksum_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Data-integrity check pass (paper SIII-D 'data integrity checks').

    The sim has no payload bytes; we verify metadata consistency instead:
    catalog size/blocks must match FS truth.
    """

    def action(e: Entry, params: dict) -> bool:
        truth = fs.stat(e.fid)
        if truth is None:
            return False
        ok = truth.size == e.size
        catalog.update_fields(e.fid, status="checked" if ok else "corrupt")
        return ok

    return action


@register_plugin("tag_status")
def tag_status_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Generic post-processing: set the v3 status field."""

    def action(e: Entry, params: dict) -> bool:
        return catalog.update_fields(e.fid, status=params.get("status", "seen"))

    def action_batch(entries: List[Entry], params: dict) -> List[bool]:
        updated = set(catalog.update_fields_batch(
            [e.fid for e in entries], status=params.get("status", "seen")))
        return [e.fid in updated for e in entries]

    action.action_batch = action_batch
    return action
