"""Pure-jnp oracle for the RWKV6 decode-step kernel (= models.rwkv6.wkv_step)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rwkv6_step_ref(r, k, v, w, u, state) -> Tuple[jax.Array, jax.Array]:
    """One token. r,k,v,w: (B,H,hd); u: (H,hd); state: (B,H,hd,hd) f32.

    y_t[j] = sum_i r[i] (S[i,j] + u[i] k[i] v[j]);  S' = diag(w) S + k v^T
    """
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return y, new_state
