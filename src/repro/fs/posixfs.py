"""POSIX backend: scan real directories (used by benchmarks vs. `find`/`du`)."""
from __future__ import annotations

import os
import stat as stat_mod
import threading
from typing import Dict, List, Optional, Tuple

from ..core.types import Entry, FsType


class PosixFs:
    """Adapter exposing a real directory tree through the FsBackend interface.

    fids are dense ids assigned per (st_dev, st_ino) as discovered.
    """

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        self._lock = threading.Lock()
        self._fid_of: Dict[Tuple[int, int], int] = {}
        self._path_of: Dict[int, str] = {}
        self._next = 1
        self._fid_for(self.root)

    def _fid_for(self, path: str) -> int:
        st = os.lstat(path)
        key = (st.st_dev, st.st_ino)
        with self._lock:
            fid = self._fid_of.get(key)
            if fid is None:
                fid = self._next
                self._next += 1
                self._fid_of[key] = fid
            self._path_of[fid] = path
            return fid

    def root_fid(self) -> int:
        return 1

    def readdir(self, fid: int) -> List[Tuple[str, int]]:
        path = self._path_of[fid]
        out = []
        try:
            with os.scandir(path) as it:
                for de in it:
                    out.append((de.name, self._fid_for(de.path)))
        except (PermissionError, FileNotFoundError):
            pass
        return out

    def stat(self, fid: int) -> Optional[Entry]:
        path = self._path_of.get(fid)
        if path is None:
            return None
        try:
            st = os.lstat(path)
        except FileNotFoundError:
            return None
        if stat_mod.S_ISDIR(st.st_mode):
            t = FsType.DIR
        elif stat_mod.S_ISLNK(st.st_mode):
            t = FsType.SYMLINK
        elif stat_mod.S_ISREG(st.st_mode):
            t = FsType.FILE
        else:
            t = FsType.OTHER
        return Entry(
            fid=fid, parent_fid=self._fid_for(os.path.dirname(path))
            if path != self.root else 0,
            name=os.path.basename(path) or "/", path=path, type=t,
            size=st.st_size, blocks=st.st_blocks * 512,
            owner=str(st.st_uid), group=str(st.st_gid),
            mode=stat_mod.S_IMODE(st.st_mode), nlink=st.st_nlink,
            atime=st.st_atime, mtime=st.st_mtime, ctime=st.st_ctime)

    def stat_batch(self, fids) -> List[Optional[Entry]]:
        """No batched lstat on POSIX — the loop just pins the interface."""
        return [self.stat(f) for f in fids]
