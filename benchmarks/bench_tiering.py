"""Out-of-core tiered catalogs (PR 8): warm-segment streaming vs resident.

The workload is the "catalog bigger than the mesh" regime: a catalog of
``n`` entries served under an HBM budget of ``budget`` padded rows —
far below the resident footprint — so the placement pass demotes quiet
shard groups to packed host segments and every policy match / report
query streams them back through the double-buffered ``(D, C+1, Rw)``
device window (copy of batch k+1 overlapped with compute of batch k).

Rows report the demote pack rate, the encoded-segment compression
ratio, warm streamed match latency against the same catalog fully
resident, and the streamed/resident throughput ratio — the "10-100M
entries on a 1M-row budget at near-resident throughput" claim.

``run_tiering_assertion`` is the tier-2 CI entry: the streamed match
must be byte-identical to the resident store AND the host oracle, the
tiering counters must prove streaming really happened (a silently
resident run fails), and streamed throughput must stay within
``min_ratio`` of resident throughput.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState,
                        PolicyDefinition, PolicyEngine, parse_expr)

NOW = float(2 ** 20)
MATCH_EXPR = "type == file and size > 3900k and last_access > 1000s"

TRAJECTORY = "tiering"


def _catalog(n: int, n_shards: int = 16) -> Catalog:
    rng = np.random.default_rng(0)
    cat = Catalog(n_shards=n_shards)
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        cat.upsert_batch([Entry(
            fid=i + 1, name=f"f{i + 1}", path=f"/fs/d{i % 64}/f{i + 1}",
            type=FsType.FILE if (i % 10) else FsType.DIR,
            size=int(rng.integers(0, 2 ** 12)) * 1024,       # f32-exact
            blocks=int(rng.integers(0, 2 ** 10)),
            owner=f"user{i % 8}", group=f"grp{i % 4}",
            hsm_state=HsmState(int(rng.integers(0, 5))),
            atime=NOW - float(rng.integers(0, 10_000)),      # f32-exact
            mtime=NOW - float(rng.integers(0, 10_000)),
        ) for i in range(lo, hi)])
    return cat


def _engine(cat: Catalog, store: DeviceColumnStore) -> PolicyEngine:
    def act(e, p):
        return True
    act.action_batch = lambda batch, p: [True] * len(batch)
    eng = PolicyEngine(cat, clock=lambda: NOW)
    eng.register(PolicyDefinition.from_config(
        name="p", action=act, scope="type == file",
        rules=[("cold", MATCH_EXPR, {})], sort_by="atime",
        n_threads=1, batch_size=4096, mutates=False))
    eng.attach_device_store(store)
    return eng


def _bench_tiering(n: int, budget: int, window_rows: int, rounds: int,
                   assert_identity: bool = False,
                   min_ratio: float = 0.0) -> list:
    cat = _catalog(n)
    expr = parse_expr(MATCH_EXPR)

    resident = DeviceColumnStore(cat, mesh=None)             # no budget
    t0 = time.perf_counter()
    resident.refresh()
    dt_resident_up = time.perf_counter() - t0

    tiered = DeviceColumnStore(cat, mesh=None, hbm_budget_rows=budget,
                               window_rows=window_rows)
    t0 = time.perf_counter()
    tiered.refresh()                     # placement + demote pack + upload
    dt_tiered_up = time.perf_counter() - t0
    tc = tiered.tiering_counters()
    if assert_identity:
        assert tc["demotions"] >= 1, (
            f"budget {budget} rows demoted nothing at n={n} "
            f"(resident rows {resident._rp * resident.n_devices})")
    seg_bytes = sum(g.segment.nbytes for g in tiered._groups
                    if g.segment is not None)
    dec_bytes = sum(g.segment.decoded_nbytes for g in tiered._groups
                    if g.segment is not None)

    # correctness first: the match SET is byte-identical at any scale
    # (per-row predicates over f32-exact values); aggregate sums follow
    # the store's documented f32 envelope — exact below 2**24 x value
    # granularity, else within one relative ulp of the float64 host
    # oracle (the streamed path float64-merges window partials, so it is
    # never LESS exact than the resident psum)
    fids_res, agg_res = resident.scan(expr, NOW)
    t0 = time.perf_counter()
    fids_str, agg_str = tiered.scan(expr, NOW)
    dt_cold_stream = time.perf_counter() - t0
    if assert_identity:
        assert sorted(fids_str.tolist()) == sorted(fids_res.tolist())
        ref = cat.arrays()
        mask = expr.mask(ref, cat.strings, NOW)
        want = ref["fid"][mask]
        assert sorted(fids_str.tolist()) == sorted(want.tolist())
        assert agg_str["count"] == agg_res["count"] == int(mask.sum())
        assert agg_str["size_profile"] == agg_res["size_profile"]
        assert agg_str["any_match"] == agg_res["any_match"]
        for key, col in (("volume", "size"), ("spc_used", "blocks")):
            exact = float(np.asarray(ref[col], np.float64)[mask].sum())
            assert np.isclose(agg_str[key], exact, rtol=1e-6), (
                key, agg_str[key], exact)
            assert np.isclose(agg_res[key], exact, rtol=1e-6), (
                key, agg_res[key], exact)
        tc = tiered.tiering_counters()
        assert tc["segments_streamed"] >= 1 and tc["windows_streamed"] >= 1

    # RunReport surfaces the per-run tiering deltas (the engine-level
    # telemetry consumers key on): assert through a real policy run
    eng = _engine(cat, tiered)
    report = eng.run("p", evaluator="policy_scan_mesh", matching="full")
    if assert_identity:
        assert report.evaluator == "policy_scan_mesh", \
            report.fallback_reason
        assert report.tiering["segments_streamed"] >= 1, report.tiering
        assert report.tiering["windows_streamed"] >= 1, report.tiering
        assert report.tiering["demoted_groups"] >= 1, report.tiering
        assert report.matched == int(agg_str["count"])  # == host count

    # warm throughput: same match on both stores, steady state
    for _ in range(1):
        resident.scan(expr, NOW)
        tiered.scan(expr, NOW)
    lat_res, lat_str = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        resident.scan(expr, NOW)
        lat_res.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        tiered.scan(expr, NOW)
        lat_str.append(time.perf_counter() - t0)
    res_s = float(np.mean(lat_res))
    str_s = float(np.mean(lat_str))
    ratio = res_s / max(str_s, 1e-9)          # streamed/resident throughput
    tc = tiered.tiering_counters()

    rows = [
        ("tiering_resident_cold_upload", 1e6 * dt_resident_up,
         f"{n}_rows_{resident.n_devices}_devices"),
        ("tiering_demote_pack", 1e6 * dt_tiered_up,
         f"budget_{budget}_rows_{tc['demoted_groups']}_of_"
         f"{tiered.n_devices}_groups_demoted"),
        ("tiering_segment_compression", 1e2 * seg_bytes /
         max(dec_bytes, 1),
         f"{seg_bytes >> 20}MiB_encoded_vs_{dec_bytes >> 20}MiB_decoded"),
        ("tiering_streamed_match_cold", 1e6 * dt_cold_stream,
         f"window_{tiered._window_rows()}_rows_per_device"),
        ("tiering_streamed_match_warm", 1e6 * str_s,
         f"{tc['windows_streamed']}_windows_{tc['window_stalls']}_stalls"),
        ("tiering_resident_match_warm", 1e6 * res_s,
         f"streamed_over_resident_throughput_{ratio:.2f}"),
    ]
    if min_ratio:
        assert ratio >= min_ratio, (
            f"streamed match throughput fell to {ratio:.2f}x of resident "
            f"(floor {min_ratio}x at n={n}, budget={budget}, "
            f"{tc['window_stalls']} stalls over "
            f"{tc['windows_streamed']} windows)")
    return rows


def run_tiering_assertion(n: int = 10_000_000, budget: int = 1_000_000,
                          min_devices: int = 4,
                          min_ratio: float = 0.6) -> list:
    """Tier-2 CI entry (ISSUE acceptance at the default sizes: >= 10M
    entries streamed under a 1M-row budget, byte-identical to the
    resident store and the host oracle, >= 60% resident throughput)."""
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= min_devices, (
        f"need >= {min_devices} devices (run under XLA_FLAGS="
        f"--xla_force_host_platform_device_count=8), have {n_dev}")
    return _bench_tiering(n, budget=budget, window_rows=0,
                          rounds=3, assert_identity=True,
                          min_ratio=min_ratio)


def run(smoke: bool = False) -> list:
    if smoke:
        # 100k rows over 8 groups pads to ~16k rows/block; a 50k budget
        # holds 2 blocks + the 2*8*1024 window reserve -> mixed residency
        return _bench_tiering(100_000, budget=50_000, window_rows=1024,
                              rounds=2, assert_identity=True)
    return _bench_tiering(2_000_000, budget=200_000, window_rows=0,
                          rounds=3, assert_identity=True)
