"""Property suite for ChangelogStream: arbitrary interleavings of
emit/emit_batch/read/ack/reset_cursor — including crash-recovery from
persist_dir mid-batch and a second named subscriber — never lose or
duplicate a record, and acked/pending stay consistent (paper SII-C2)."""
import shutil
import tempfile

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                 invariant, rule)

from repro.core import ChangelogRecord, ChangelogStream, ChangelogType

SUB = "policy-engine"


class StreamMachine(RuleBasedStateMachine):
    """Model: the stream is the sequence 1..emitted; each consumer owns a
    (cursor, acked) pair with acked <= cursor <= emitted. ``read`` must
    return exactly the contiguous run after the cursor — no loss, no dup,
    no reordering — across acks, cursor resets, and crash restarts."""

    @initialize()
    def setup(self) -> None:
        self.dir = tempfile.mkdtemp(prefix="chlog-prop-")
        self.stream = ChangelogStream(mdt=0, persist_dir=self.dir)
        self.stream.subscribe(SUB)
        self.emitted = 0
        self.model = {None: [0, 0], SUB: [0, 0]}   # name -> [cursor, acked]

    def teardown(self) -> None:
        self.stream.close()
        shutil.rmtree(self.dir, ignore_errors=True)

    # -- producer rules ---------------------------------------------------------
    @rule(n=st.integers(1, 5))
    def emit(self, n) -> None:
        for _ in range(n):
            rec = self.stream.emit(ChangelogType.CREAT, fid=self.emitted + 1)
            self.emitted += 1
            assert rec.seq == self.emitted          # dense, monotonic seqs

    @rule(n=st.integers(1, 6))
    def emit_batch(self, n) -> None:
        self.stream.emit_batch([
            ChangelogRecord(seq=0, type=ChangelogType.CLOSE, fid=i)
            for i in range(n)])
        self.emitted += n

    # -- consumer rules ---------------------------------------------------------
    @rule(k=st.integers(1, 7), who=st.sampled_from([None, SUB]))
    def read(self, k, who) -> None:
        recs = self.stream.read(max_records=k, subscriber=who)
        cursor = self.model[who][0]
        expect = list(range(cursor + 1, min(cursor + k, self.emitted) + 1))
        assert [r.seq for r in recs] == expect      # exactly-once, in order
        if expect:
            self.model[who][0] = expect[-1]

    @rule(who=st.sampled_from([None, SUB]), frac=st.floats(0.0, 1.0))
    def ack_some(self, who, frac) -> None:
        cursor, acked = self.model[who]
        seq = acked + int((cursor - acked) * frac)
        self.stream.ack(seq, subscriber=who)
        self.model[who][1] = max(acked, seq)
        self.model[who][0] = max(cursor, self.model[who][1])

    @rule(who=st.sampled_from([None, SUB]))
    def over_ack_is_clamped(self, who) -> None:
        """Acking past the head must not swallow later emissions."""
        self.stream.ack(self.emitted + 5, subscriber=who)
        self.model[who] = [self.emitted, self.emitted]

    @rule(who=st.sampled_from([None, SUB]))
    def reset_cursor(self, who) -> None:
        self.stream.reset_cursor(subscriber=who)
        self.model[who][0] = self.model[who][1]     # unacked re-delivered

    # -- crash/restart ----------------------------------------------------------
    @rule()
    def crash_and_recover(self) -> None:
        """Close mid-stream; a fresh stream on the same dir re-delivers
        every unacked record to every subscriber."""
        self.stream.close()
        self.stream = ChangelogStream(mdt=0, persist_dir=self.dir)
        self.stream.subscribe(SUB)
        for who in self.model:
            self.model[who][0] = self.model[who][1]  # cursor back to acked
            assert self.stream.pending(subscriber=who) == \
                self.emitted - self.model[who][1]

    # -- invariants --------------------------------------------------------------
    @invariant()
    def acked_and_pending_consistent(self) -> None:
        assert self.stream.acked == self.model[None][1]
        assert self.stream.acked_of(SUB) == self.model[SUB][1]
        for who in self.model:
            assert self.stream.pending(subscriber=who) == \
                self.emitted - self.model[who][1]


TestChangelogStreamProperties = StreamMachine.TestCase
TestChangelogStreamProperties.settings = settings(
    max_examples=40, stateful_step_count=30, deadline=None)
TestChangelogStreamProperties = pytest.mark.slow(TestChangelogStreamProperties)


@pytest.mark.slow
@hypothesis.given(st.lists(st.integers(1, 50), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_interleaved_batches_assign_dense_seqs(batch_sizes):
    s = ChangelogStream()
    total = 0
    for n in batch_sizes:
        s.emit_batch([ChangelogRecord(seq=0, type=ChangelogType.CREAT, fid=i)
                      for i in range(n)])
        total += n
    seqs = [r.seq for r in s.read(max_records=10 ** 6)]
    assert seqs == list(range(1, total + 1))
