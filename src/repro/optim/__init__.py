from .adamw import AdamW, OptState
from .schedules import cosine_warmup
from .grad_compression import (compress_int8, decompress_int8,
                               make_compressed_allreduce)

__all__ = ["AdamW", "OptState", "cosine_warmup", "compress_int8",
           "decompress_int8", "make_compressed_allreduce"]
