"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d RoPE (half head-dim), qkv bias. [arXiv:2406.12793; hf]
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

_PATTERN = (LayerSpec(mix=ATTN_FULL),)

CONFIG = ModelConfig(
    name="chatglm3_6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, head_dim=128,
    d_ff=13696, vocab=65024,
    pattern=_PATTERN, rope_fraction=0.5, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="chatglm3_smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN, rope_fraction=0.5, qkv_bias=True,
)
