"""Public policy-scan op: pads, dispatches kernel/oracle, unpads."""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANE, policy_scan_pallas
from .ref import N_AGG, policy_scan_multi_ref, policy_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                operands: jax.Array, size_col: int = 0, blocks_col: int = 1,
                valid_col: int = -1, use_kernel: bool = True,
                tile: int = 8 * LANE) -> Tuple[jax.Array, jax.Array]:
    """Evaluate a predicate program over a columnar table + aggregates.

    cols: (n_cols, N) f32. Returns (mask (N,) f32, agg (N_AGG,) f32).
    Rows are padded to the tile size with an all-invalid pad (mask forced 0
    via a validity column the wrapper appends when ``valid_col`` < 0).
    """
    n_cols, n = cols.shape
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    mask, agg = policy_scan_pallas(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col, tile=tile,
        interpret=not _on_tpu()) if use_kernel else policy_scan_ref(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col)
    return mask[:n], agg


@partial(jax.jit, static_argnames=("size_col", "blocks_col"))
def policy_scan_multi(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                      operands: jax.Array, size_col: int = 0,
                      blocks_col: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Evaluate R padded predicate programs over one column stack.

    cols: (n_cols, N) f32; ops/colidx/operands: (R, P), OP_NOP padded.
    Returns (masks (R, N) f32, agg (N_AGG,) f32 for program 0). One
    columnar pass: matching and size/blocks aggregation fuse in one scan.
    """
    return policy_scan_multi_ref(cols, ops.astype(jnp.int32),
                                 colidx.astype(jnp.int32),
                                 operands.astype(jnp.float32),
                                 size_col=size_col, blocks_col=blocks_col)


def column_stack(arrays) -> jax.Array:
    """Stack a Catalog.arrays() dict into the (n_cols, N) f32 kernel layout."""
    from ...core.policy import KERNEL_COLUMNS
    return jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)


def match_programs(arrays, exprs, strings, now: float,
                   use_kernel: Optional[bool] = None
                   ) -> Tuple[List[np.ndarray], dict]:
    """Evaluate several core.policy Exprs over catalog columns at once.

    ``exprs[0]`` is the combined match criteria (its fused aggregates are
    returned); further exprs are typically per-rule conditions for
    vectorized attribution. ``use_kernel=None`` selects the Pallas kernel
    on TPU and the jitted oracle everywhere else. Raises PolicyError if any
    expr contains host-only (glob) predicates — callers fall back to the
    numpy mask path.
    """
    from ...core.policy import KERNEL_COLUMNS, compile_programs
    ops, colidx, operands = compile_programs(exprs, strings, now)
    kcols = column_stack(arrays)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        # The Pallas kernel evaluates one program per launch; the combined
        # criteria (program 0) fuses mask + aggregation in a single HBM pass,
        # rule programs reuse the resident column stack.
        masks, agg = [], None
        for r in range(ops.shape[0]):
            m, a = policy_scan(kcols, jnp.asarray(ops[r]),
                               jnp.asarray(colidx[r]),
                               jnp.asarray(operands[r]), size_col=size_col,
                               blocks_col=blocks_col, use_kernel=True)
            if r == 0:
                agg = a
            masks.append(np.asarray(m) > 0.5)
    else:
        m, agg = policy_scan_multi(kcols, jnp.asarray(ops),
                                   jnp.asarray(colidx),
                                   jnp.asarray(operands), size_col=size_col,
                                   blocks_col=blocks_col)
        m = np.asarray(m) > 0.5
        masks = [m[r] for r in range(m.shape[0])]
    agg_np = np.asarray(agg)
    return masks, {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }


def scan_catalog(catalog, expr, now: float, use_kernel: bool = True
                 ) -> Tuple[np.ndarray, dict]:
    """Run a core.policy expression over a Catalog via the kernel path.

    Only numeric/categorical predicates compile to the kernel program;
    glob predicates raise PolicyError (callers fall back to Expr.mask).
    Returns (matching fids, aggregate dict).
    """
    from ...core.policy import KERNEL_COLUMNS, compile_program
    arrays = catalog.arrays()
    ops, colidx, operands = compile_program(expr, catalog.strings, now)
    cols = jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    mask, agg = policy_scan(cols, jnp.asarray(ops), jnp.asarray(colidx),
                            jnp.asarray(operands), size_col=size_col,
                            blocks_col=blocks_col, use_kernel=use_kernel)
    mask_np = np.asarray(mask) > 0.5
    agg_np = np.asarray(agg)
    return arrays["fid"][mask_np], {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
