"""Chunked online-softmax attention vs naive reference (hypothesis sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, never hard-error collection
from hypothesis import given, settings, strategies as st

from repro.models.components import attention


def naive_attention(q, k, v, q_pos, kv_pos, causal, window, softcap):
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    k = np.repeat(np.asarray(k, np.float32), G, axis=2)
    v = np.repeat(np.asarray(v, np.float32), G, axis=2)
    qf = np.asarray(q, np.float32) / np.sqrt(hd)
    s = np.einsum("bqhd,bkhd->bhqk", qf, k)
    if softcap:
        s = softcap * np.tanh(s / softcap)
    mask = np.asarray(kv_pos)[None, :] >= 0
    if causal:
        mask = mask & (np.asarray(kv_pos)[None, :]
                       <= np.asarray(q_pos)[:, None])
    if window:
        mask = mask & (np.asarray(kv_pos)[None, :]
                       > np.asarray(q_pos)[:, None] - window)
    s = np.where(mask[None, None], s, -np.inf)
    mx = np.max(s, axis=-1, keepdims=True)
    mx = np.where(np.isfinite(mx), mx, 0.0)
    p = np.exp(s - mx)
    p = np.where(np.isfinite(s), p, 0.0)
    denom = np.maximum(p.sum(-1, keepdims=True), 1e-20)
    return np.einsum("bhqk,bkhd->bqhd", p / denom, v)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 99),
    sk=st.sampled_from([8, 16, 32, 64]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    causal=st.booleans(),
    window=st.sampled_from([0, 8]),
    softcap=st.sampled_from([None, 20.0]),
    chunk=st.sampled_from([4, 8, 16, 1024]),
)
def test_attention_matches_naive(seed, sk, heads, causal, window, softcap,
                                 chunk):
    H, K = heads
    rng = np.random.default_rng(seed)
    B, hd = 2, 8
    sq = sk
    q = jnp.asarray(rng.standard_normal((B, sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, sk, K, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, sk, K, hd)), jnp.float32)
    q_pos = jnp.arange(sq)
    kv_pos = jnp.arange(sk)
    out = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                    window=window, logit_softcap=softcap, kv_chunk=chunk)
    ref = naive_attention(q, k, v, q_pos, kv_pos, causal, window, softcap)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-3, rtol=2e-3)


def test_invalid_slots_are_masked():
    """Cache slots with kv_pos == -1 must not contribute."""
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 8, 2, 4
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    kv_pos_full = jnp.arange(S)
    out_full = attention(q, k, v, q_pos=jnp.array([S - 1]),
                         kv_pos=kv_pos_full, causal=True)
    # poison the masked half; mark invalid
    k2 = k.at[:, 4:].set(99.0)
    v2 = v.at[:, 4:].set(99.0)
    kv_pos_half = jnp.where(jnp.arange(S) < 4, jnp.arange(S), -1)
    out_half = attention(q, k2, v2, q_pos=jnp.array([S - 1]),
                         kv_pos=kv_pos_half, causal=True)
    ref_half = attention(q, k[:, :4], v[:, :4], q_pos=jnp.array([S - 1]),
                         kv_pos=jnp.arange(4), causal=True)
    np.testing.assert_allclose(np.asarray(out_half), np.asarray(ref_half),
                               atol=1e-5)
    assert not np.allclose(np.asarray(out_full), np.asarray(out_half))


def test_rwkv_chunked_vs_serial():
    from repro.models.rwkv6 import wkv_chunked, wkv_ref
    rng = np.random.default_rng(5)
    B, S, H, hd = 2, 32, 2, 8
    mk = lambda s=0.5: jnp.asarray(rng.standard_normal((B, S, H, hd)) * s,
                                   jnp.float32)
    r, k, v = mk(), mk(), mk()
    lw = -jnp.abs(mk(1.0))
    u = jnp.asarray(rng.standard_normal((H, hd)) * 0.5, jnp.float32)
    for chunk in (4, 8, 16, 32):
        y, s_fin = wkv_chunked(r, k, v, lw, u, chunk=chunk)
        y_ref, s_ref = wkv_ref(r, k, v, lw, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                                   atol=1e-4, rtol=1e-4)


def test_rglru_assoc_scan_vs_serial():
    from repro.models.components import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_ref
    rng = np.random.default_rng(6)
    B, S, R = 2, 33, 8
    la = jnp.asarray(-np.abs(rng.standard_normal((B, S, R))) * 0.3,
                     jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, R)), jnp.float32)
    h = rglru_scan(la, b)
    h_ref = rglru_ref(la, b, jnp.zeros((B, R)))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-5)
