"""`rbh-report` / `rbh-find` / `rbh-du` clones (C6, C9) — answer from the DB.

All queries here run against the catalog (vectorized column masks), the
pre-aggregated stats, or the on-device profile cube — never against the
filesystem, which is the paper's point: *"all these metadata queries do not
generate extra load on the filesystem"*.

With :meth:`Reports.attach_device_store`, ``find``/``top_files``/``du``
additionally go **mesh-resident**: predicates evaluate and top-k/range
aggregates reduce over the device store's sharded column blocks under
``shard_map``, and only the winning rows' paths come back through the
store's host mirrors — a warm query never calls ``Catalog.arrays()``.
Queries the resident plane cannot serve (glob predicates, non-kernel
columns) raise :class:`~repro.core.policy.PolicyError` inside the store
and fall back to the host folds below, which also stay on as the
byte-identical differential oracle (``tests/core/test_mesh_reports.py``).
The fallback is recorded in :attr:`Reports.last_fallback_reason` —
cleared again by the next store-served success, so the telemetry always
describes the *most recent* query, not a sticky historical one.

With :meth:`Reports.attach_grants`, every serving query additionally
accepts ``subject=`` (multi-tenant scoping): the store path ANDs that
subject's pre-materialized permission bitset into the kernel's match
mask (``DeviceColumnStore`` permissions plane), and the host folds
filter by :meth:`~repro.core.grants.GrantTable.visible_mask` — the two
stay byte-identical (``tests/core/test_tenant_scoping.py``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from .catalog import Catalog
from .policy import Expr, KERNEL_COLUMNS, PolicyError, parse_expr
from .profiles import ProfileCube
from .stats import DirUsage, StatsAggregator
from .telemetry import counter_attr, slug, state_attr
from .types import FsType, format_size


class _PathIndex:
    """Sorted path column + subtree prefix sums for O(log n) ``du``.

    Built once per **shard** version: every path under ``prefix/`` is
    contiguous in the sorted order — bounded below by ``prefix + "/"`` and
    above by ``prefix + "0"`` ('0' is the successor of '/') — so a subtree
    aggregate is two binary searches into precomputed prefix sums instead
    of a per-path scan.
    """

    def __init__(self, cols) -> None:
        paths = np.asarray(cols["_paths"])
        order = np.argsort(paths, kind="stable")
        self.spaths = paths[order]
        is_file = (cols["type"][order] == int(FsType.FILE))
        fsize = np.where(is_file, cols["size"][order], 0)
        fblocks = np.where(is_file, cols["blocks"][order], 0)
        # leading 0 so any [lo, hi) range sum is csum[hi] - csum[lo]
        self.csize = np.concatenate([[0], np.cumsum(fsize)])
        self.cblocks = np.concatenate([[0], np.cumsum(fblocks)])
        self.cfiles = np.concatenate([[0], np.cumsum(is_file.astype(np.int64))])

    def _range(self, lo_key: str, hi_key: str, side_hi: str = "left") -> dict:
        lo = int(np.searchsorted(self.spaths, lo_key, side="left"))
        hi = int(np.searchsorted(self.spaths, hi_key, side=side_hi))
        return {
            "count": hi - lo,
            "files": int(self.cfiles[hi] - self.cfiles[lo]),
            "volume": int(self.csize[hi] - self.csize[lo]),
            "spc_used": int(self.cblocks[hi] - self.cblocks[lo]),
        }

    def du(self, path_prefix: str) -> dict:
        prefix = path_prefix.rstrip("/")
        sub = self._range(prefix + "/", prefix + "0")
        root = self._range(prefix, prefix, side_hi="right")
        return {k: sub[k] + root[k] for k in sub}


class Reports:
    # serving counters, registry-backed (attach_device_store): they
    # mirror the engine's RunReport telemetry — store_served /
    # host_served tally where each query answered, index_rebuilds counts
    # sorted-path index rebuilds, last_fallback_reason says why the most
    # recent query fell back to the host fold (None = none did)
    store_served = counter_attr(
        "reports_store_served", "queries answered mesh-resident")
    host_served = counter_attr(
        "reports_host_served", "queries answered by host folds")
    index_rebuilds = counter_attr(
        "reports_index_rebuilds", "sorted-path index rebuilds")
    last_fallback_reason = state_attr(
        "reports_last_fallback_reason",
        "why the most recent query fell back to the host fold")

    def __init__(self, catalog: Catalog, stats: Optional[StatsAggregator] = None,
                 clock=time.time, profiles: Optional[ProfileCube] = None
                 ) -> None:
        self.catalog = catalog
        self.stats = stats
        self.profiles = profiles
        self.clock = clock
        self.telemetry = catalog.telemetry
        self._tlabels = {"reports": catalog.telemetry.instance("reports")}
        # one path index per shard, rebuilt only when THAT shard's version
        # ticked — churn in one shard leaves the other indexes warm
        self._pindexes: Dict[int, _PathIndex] = {}
        self._pversions: Dict[int, int] = {}
        self.index_rebuilds = 0
        self.device_store = None
        self.store_served = 0
        self.host_served = 0
        self.last_fallback_reason = None
        # multi-tenant scoping (attach_grants): the shared GrantTable
        # behind every subject= query
        self.grants = None

    def attach_device_store(self, store) -> "Reports":
        """Serve ``find``/``top_files``/``du`` from a
        :class:`~repro.core.device_store.DeviceColumnStore`.

        Enables the store's reports plane (sorted-path rank row + host
        path mirrors beside the resident columns) — and, when a
        :class:`~repro.core.grants.GrantTable` is already attached, its
        permissions plane too. Host folds stay available as the
        automatic fallback for queries the plane cannot express — and as
        the differential oracle.
        """
        if store.catalog is not self.catalog:
            raise ValueError("device store is bound to a different catalog")
        store.enable_reports_plane()
        self.device_store = store
        if self.grants is not None:
            store.enable_permissions_plane(self.grants)
        return self

    def tiering_counters(self) -> Dict[str, int]:
        """Tiered-residency telemetry of the attached device store
        (demotions / promotions / segments_streamed / windows_streamed /
        window_stalls, plus resident_groups / demoted_groups gauges) —
        empty when no store is attached or the store holds everything
        resident. Serving queries over demoted groups stream their warm
        segments through the double-buffered device window instead of
        falling back to the host folds (see docs/architecture.md,
        "Tiered residency"); the permissions plane scopes streamed
        windows exactly like resident rows."""
        if self.device_store is None:
            return {}
        return self.device_store.tiering_counters()

    def attach_grants(self, grants) -> "Reports":
        """Wire a :class:`~repro.core.grants.GrantTable` so every serving
        query accepts ``subject=``. With a device store attached this
        enables its permissions plane (scoping becomes one fused AND on
        the mesh); without one the host folds filter by
        :meth:`GrantTable.visible_mask`."""
        self.grants = grants
        if self.device_store is not None:
            self.device_store.enable_permissions_plane(grants)
        return self

    def _grant_mask(self, subject: str, cols) -> np.ndarray:
        """Host-side visibility mask for ``subject`` — the scalar oracle
        the store's bitset path is pinned to byte-for-byte."""
        if self.grants is None:
            raise RuntimeError(
                "subject= scoping needs attach_grants(GrantTable)")
        return self.grants.visible_mask(subject, cols,
                                        self.catalog.strings)

    def reset_counters(self) -> None:
        """Scrape boundary: delegates to
        :meth:`~repro.core.telemetry.MetricRegistry.reset`, so the
        serving counters, ``last_fallback_reason``, the tiering and
        permission counters of any attached device store, and every
        other counter family on this catalog's registry clear
        *together* — a scrape never sees serving zeroed but tiering
        still accumulating."""
        self.telemetry.reset()

    # -- serving telemetry ------------------------------------------------------
    def _observe(self, kind: str, subject: Optional[str], source: str,
                 t0: float) -> None:
        """Per-query-kind serve latency histogram
        (``reports_serve_seconds{kind=,scoped=,source=}``)."""
        self.telemetry.histogram(
            "reports_serve_seconds", help="report query latency",
            kind=kind, scoped=str(subject is not None).lower(),
            source=source, **self._tlabels
        ).observe(time.perf_counter() - t0)

    def _fallback(self, kind: str, exc: Exception) -> None:
        """Count a host-fold downgrade (``fallback{stage=,reason=}``) —
        the counter sibling of ``last_fallback_reason``, so exports can
        assert "no silent fallback" without string-scraping."""
        self.telemetry.counter(
            "fallback", help="evaluator/serving downgrades",
            stage=f"reports.{kind}", reason=slug(str(exc)),
            **self._tlabels).inc()

    def _shard_indexes(self) -> List[_PathIndex]:
        """(Re)build the per-shard sorted path indexes that went stale.

        A rebuild snapshots only the columns the index reads (type/size/
        blocks + the path gather) — not the shard's full column stack.
        """
        out = []
        for sid, shard in enumerate(self.catalog.shards):
            version = shard.version
            if self._pversions.get(sid) != version:
                cols, snap = shard.snapshot(names=("type", "size", "blocks"))
                cols["_paths"] = snap.gather("_paths")  # type: ignore
                self._pindexes[sid] = _PathIndex(cols)
                self._pversions[sid] = version
                self.index_rebuilds += 1
            out.append(self._pindexes[sid])
        return out

    # -- rbh-report ---------------------------------------------------------------
    def _backend(self):
        if self.profiles is not None:
            return self.profiles
        if self.stats is None:
            raise RuntimeError("no stats aggregator or profile cube attached")
        return self.stats

    def _profiles_backend(self):
        """Scoped (``subject=``) report queries need the profile cube —
        the scalar aggregator keeps no per-row grant information."""
        if self.profiles is None:
            raise RuntimeError(
                "subject= report scoping needs an attached ProfileCube")
        return self.profiles

    def report_user(self, user: str,
                    subject: Optional[str] = None) -> List[dict]:
        """O(1) per-user summary (pre-aggregated / profile cube)."""
        if subject is not None:
            return self._profiles_backend().report_user(user,
                                                        subject=subject)
        return self._backend().report_user(user)

    def report_group(self, grp: str,
                     subject: Optional[str] = None) -> List[dict]:
        if subject is not None:
            return self._profiles_backend().report_group(grp,
                                                         subject=subject)
        return self._backend().report_group(grp)

    def report_types(self, subject: Optional[str] = None) -> Dict[str, dict]:
        if subject is not None:
            return self._profiles_backend().report_types(subject=subject)
        return self._backend().report_types()

    def report_hsm(self, subject: Optional[str] = None) -> Dict[str, dict]:
        if subject is not None:
            return self._profiles_backend().report_hsm(subject=subject)
        return self._backend().report_hsm()

    def user_size_profile(self, user: str,
                          subject: Optional[str] = None) -> Dict[str, int]:
        if subject is not None:
            return self._profiles_backend().user_size_profile(
                user, subject=subject)
        return self._backend().user_size_profile(user)

    def top_users(self, by: str = "volume", k: int = 10,
                  type_: FsType = FsType.FILE,
                  subject: Optional[str] = None) -> List[dict]:
        if subject is not None:
            return self._profiles_backend().top_users(by=by, k=k,
                                                      type_=type_,
                                                      subject=subject)
        return self._backend().top_users(by=by, k=k, type_=type_)

    def age_profile(self, user: Optional[str] = None,
                    subject: Optional[str] = None) -> Dict[str, dict]:
        """Data-age profile (profile-cube only — the scalar aggregator
        keeps no age axis)."""
        if self.profiles is None:
            raise RuntimeError("age profiles need an attached ProfileCube")
        return self.profiles.age_profile(user, subject=subject)

    def format_user_report(self, user: str) -> str:
        rows = self.report_user(user)
        lines = ["user, type, count, spc_used, avg_size"]
        for r in rows:
            lines.append(f"{r['user']}, {r['type']}, {r['count']}, "
                         f"{format_size(r['spc_used'])}, "
                         f"{format_size(r['avg_size'])}")
        return "\n".join(lines)

    # -- rbh-find -----------------------------------------------------------------
    def find(self, criteria: str, limit: int = 0,
             subject: Optional[str] = None) -> List[str]:
        """DB-backed `find`: returns matching paths.

        Store-backed when a device store is attached: the predicate runs
        as one mesh program over the resident columns and only winning
        rows' paths return (same order as the host fold). Predicates the
        kernel can't compile (e.g. name globs) fall back to the host.
        ``subject=`` scopes the listing to that subject's grants."""
        t0 = time.perf_counter()
        expr = parse_expr(criteria)
        if self.device_store is not None:
            try:
                out = self.device_store.find_paths(expr, self.clock(),
                                                   limit=limit,
                                                   subject=subject)
                self.store_served += 1
                self.last_fallback_reason = None
                self._observe("find", subject, "store", t0)
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"find: {exc}"
                self._fallback("find", exc)
        self.host_served += 1
        cols = self.catalog.arrays()
        mask = expr.mask(cols, self.catalog.strings, self.clock())
        if subject is not None:
            mask = mask & self._grant_mask(subject, cols)
        idx = np.nonzero(mask)[0]
        if limit:
            idx = idx[:limit]
        paths = cols["_paths"]
        out = [paths[i] for i in idx]
        self._observe("find", subject, "host", t0)
        return out

    # -- rbh-du --------------------------------------------------------------------
    def _du_host(self, path_prefix: str,
                 subject: Optional[str] = None) -> dict:
        """Host `du` fold. Unscoped queries answer from the per-shard
        sorted-path prefix sums; scoped ones cannot (the visibility mask
        varies per subject, invalidating the precomputed sums), so they
        fold the grant-filtered columns directly — which is also the
        shape of the differential oracle the store path is pinned to."""
        if subject is None:
            out = {"count": 0, "files": 0, "volume": 0, "spc_used": 0}
            for index in self._shard_indexes():
                part = index.du(path_prefix)
                for k in out:
                    out[k] += part[k]
            return out
        cols = self.catalog.arrays()
        vis = self._grant_mask(subject, cols)
        prefix = path_prefix.rstrip("/")
        p = np.asarray(cols["_paths"])
        m = vis & ((p == prefix) | np.char.startswith(p, prefix + "/"))
        f = m & (cols["type"] == int(FsType.FILE))
        return {"count": int(m.sum()), "files": int(f.sum()),
                "volume": int(np.asarray(cols["size"],
                                         np.int64)[f].sum()),
                "spc_used": int(np.asarray(cols["blocks"],
                                           np.int64)[f].sum())}

    def du(self, path_prefix: str, subject: Optional[str] = None) -> dict:
        """DB-backed `du -s`: subtree aggregate via sorted-prefix-range.

        Answers from per-shard sorted path indexes + prefix sums cached
        per :attr:`CatalogShard.version` — two binary searches per shard
        per query, rebuilding only the indexes of shards that churned
        (see ``benchmarks/bench_find_du.py``).

        Store-backed when a device store is attached: rank bounds from
        the host path mirrors, one fused on-device range-aggregate psum.
        ``subject=`` counts only rows that subject may see.
        """
        t0 = time.perf_counter()
        if self.device_store is not None:
            try:
                out = self.device_store.du(path_prefix, subject=subject)
                self.store_served += 1
                self.last_fallback_reason = None
                self._observe("du", subject, "store", t0)
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"du: {exc}"
                self._fallback("du", exc)
        self.host_served += 1
        out = self._du_host(path_prefix, subject)
        self._observe("du", subject, "host", t0)
        return out

    def du_many(self, path_prefixes: List[str],
                subject: Optional[str] = None) -> List[dict]:
        """Batched `du -s`: one index refresh amortized over many subtrees
        (the store-backed path needs no host index prefetch).

        If the store rejects mid-batch (detach, structural churn, an
        unservable prefix), the FIRST ``PolicyError`` flips the whole
        remainder to the host path and prefetches the shard indexes
        once — instead of every remaining prefix paying its own fallback
        round-trip through the store."""
        if self.device_store is None and subject is None:
            self._shard_indexes()
        use_store = self.device_store is not None
        out = []
        for p in path_prefixes:
            t0 = time.perf_counter()
            if use_store:
                try:
                    out.append(self.device_store.du(p, subject=subject))
                    self.store_served += 1
                    self.last_fallback_reason = None
                    self._observe("du_many", subject, "store", t0)
                    continue
                except PolicyError as exc:
                    self.last_fallback_reason = f"du: {exc}"
                    self._fallback("du", exc)
                    use_store = False
                    if subject is None:
                        self._shard_indexes()   # one prefetch, not per-prefix
            self.host_served += 1
            out.append(self._du_host(p, subject))
            self._observe("du_many", subject, "host", t0)
        return out

    def bind_dir_usage(self, du: DirUsage) -> DirUsage:
        """Route a :class:`DirUsage`'s deeper-than-``max_depth`` queries to
        the index-backed :meth:`du` (the documented depth contract)."""
        du.deep_du = self.du
        return du

    # -- top-N listings (paper SII-B3) ----------------------------------------------
    def top_files(self, by: str = "size", k: int = 10,
                  desc: bool = True,
                  subject: Optional[str] = None) -> List[dict]:
        """Top-N files by any kernel column (size/atime/...), exact ties.

        Store-backed when a device store is attached: per-device top-k
        establishes the global threshold, a mask pass recovers every
        candidate (incl. cross-device ties), and only those rows' paths
        come back — ordering matches the host fold byte-for-byte.
        ``subject=`` ranks only rows that subject may see."""
        t0 = time.perf_counter()
        if self.device_store is not None and by in KERNEL_COLUMNS:
            try:
                out = self.device_store.top_files(by=by, k=k, desc=desc,
                                                  now=self.clock(),
                                                  subject=subject)
                self.store_served += 1
                self.last_fallback_reason = None
                self._observe("top_files", subject, "store", t0)
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"top_files: {exc}"
                self._fallback("top_files", exc)
        self.host_served += 1
        cols = self.catalog.arrays()
        sel = cols["type"] == int(FsType.FILE)
        if subject is not None:
            sel = sel & self._grant_mask(subject, cols)
        fidx = np.nonzero(sel)[0]
        vals = cols[by][fidx]
        if vals.size == 0:
            return []
        k = min(k, vals.size)
        order = np.argsort(vals, kind="stable")
        order = order[::-1][:k] if desc else order[:k]
        paths = cols["_paths"]
        out = [{"path": paths[fidx[o]], by: float(vals[o]),
                "fid": int(cols["fid"][fidx[o]])} for o in order]
        self._observe("top_files", subject, "host", t0)
        return out

    def top_dirs_by_count(self, k: int = 10) -> List[dict]:
        """Top directories by direct child count (one vector groupby)."""
        cols = self.catalog.arrays()
        parents = cols["parent_fid"]
        uniq, counts = np.unique(parents[parents >= 0], return_counts=True)
        if uniq.size == 0:
            return []
        k = min(k, uniq.size)
        top = np.argsort(counts)[::-1][:k]
        out = []
        for i in top:
            e = self.catalog.get(int(uniq[i]))
            out.append({"path": e.path if e else f"fid:{int(uniq[i])}",
                        "children": int(counts[i])})
        return out

    def oldest_files(self, k: int = 10,
                     subject: Optional[str] = None) -> List[dict]:
        return self.top_files(by="atime", k=k, desc=False, subject=subject)
