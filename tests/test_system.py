"""End-to-end system tests: the full stack working together."""
import numpy as np
import pytest


def test_train_loop_end_to_end(tmp_path):
    """Train a tiny LM with the real stack: data pipeline -> train_step ->
    robinhood-managed checkpoints -> injected failure -> restart -> loss
    decreases across the whole run."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.models import Model
    from repro.optim import AdamW, cosine_warmup
    from repro.runtime.checkpoint import CheckpointManager
    from repro.runtime.fault import SimulatedFailure, run_with_restarts
    from repro.train import init_train_state, make_train_step

    cfg = get_config("chatglm3_6b", smoke=True)
    model = Model(cfg, kv_chunk=16)
    opt = AdamW(lr=cosine_warmup(3e-3, 10, 60), weight_decay=0.0)
    pipe = DataPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)
    step_jit = jax.jit(make_train_step(model, opt))
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=2)
    losses = []
    failures = {17}

    def init_state():
        pipe.state.next_step = 0
        return init_train_state(model, opt, jax.random.PRNGKey(0))

    def step_fn(state, step):
        if step in failures:
            failures.discard(step)
            raise SimulatedFailure(host=1, step=step)
        b = pipe.batch_for(step)      # deterministic replay on restart
        batch = {"tokens": jnp.asarray(b["tokens"])[None],
                 "labels": jnp.asarray(b["labels"])[None]}
        state, metrics = step_jit(state, batch)
        losses.append(float(metrics["loss"]))
        return state

    final, restarts, replayed = run_with_restarts(
        train_steps=40, step_fn=step_fn, init_state=init_state, ckpt=cm,
        ckpt_interval=10)
    assert restarts == 1
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert cm.steps()  # checkpoints retained


def test_lustre_monitoring_end_to_end(fake_clock):
    """The paper's headline scenario: a filesystem under load, mirrored in
    soft real-time, policies keeping OSTs under watermark, O(1) reports."""
    from repro.core import (Catalog, EventPipeline, HsmCoordinator,
                            PipelineConfig, PolicyEngine, Reports, Scanner,
                            StatsAggregator)
    from repro.fs import HsmBackend, LustreSim

    fs = LustreSim(n_osts=4, ost_capacity=100_000, n_mdts=2,
                   hsm=HsmBackend(), clock=fake_clock)
    home = fs.mkdir(fs.root_fid(), "home")
    users = {u: fs.mkdir(home, u, owner=u) for u in ("ann", "bob")}

    cat = Catalog(n_shards=4)
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    Scanner(fs, cat, n_threads=2).scan()
    pipes = [EventPipeline(fs, cat, fs.changelog.stream(m),
                           PipelineConfig()) for m in range(2)]
    eng = PolicyEngine(cat, clock=fake_clock)
    coord = HsmCoordinator(fs, cat, eng, archive_age="10s",
                           high_wm=60.0, low_wm=30.0)

    # workload: users create files; DB follows via changelog only
    fids = []
    for i in range(40):
        u = "ann" if i % 2 else "bob"
        f = fs.create(users[u], f"f{i}", owner=u, uid=u, jobid=f"job{i%3}")
        fs.write(f, 8000, uid=u)
        fids.append(f)
    for p in pipes:
        p.process_once(10000)
    assert len(cat) == fs.count()

    rep = Reports(cat, stats)
    ann = [r for r in rep.report_user("ann") if r["type"] == "file"][0]
    assert ann["count"] == 20 and ann["volume"] == 160_000

    # archive then trigger watermark purges
    fake_clock.advance(60)
    coord.archive_pass()
    purges = coord.space_check()
    assert purges
    for o in fs.osts:
        assert o.usage_pct <= 60.0
    for p in pipes:
        p.process_once(10000)   # HSM events flow back into the DB
    hsm_rep = stats.report_hsm()
    assert hsm_rep.get("released", {}).get("count", 0) > 0


def test_paged_serving_with_tiering_end_to_end():
    """Serve batched requests while pages migrate hot<->cold underneath."""
    from repro.serve.engine import PagedLMConfig, Request, ServingEngine

    cfg = PagedLMConfig(n_pages=12, page_size=4, n_layers=2,
                        high_wm=70.0, low_wm=40.0)
    eng = ServingEngine(cfg, seed=1)
    reqs = [Request(req_id=i, prompt=[(7 * i + j) % cfg.vocab
                                      for j in range(6)], max_new=8)
            for i in range(4)]
    done = eng.run(reqs)
    assert all(r.done and len(r.generated) == 8 for r in done)
    # greedy decoding is deterministic: same prompts -> same outputs
    eng2 = ServingEngine(cfg, seed=1)
    reqs2 = [Request(req_id=i, prompt=[(7 * i + j) % cfg.vocab
                                       for j in range(6)], max_new=8)
             for i in range(4)]
    done2 = eng2.run(reqs2)
    assert [r.generated for r in done] == [r.generated for r in done2]
    reports = eng.tier_report()
    assert all(r["hot_pages"] == 0 for r in reports)  # all freed at finish


def test_kv_tiering_watermark_and_restore():
    from repro.kvcache import PagePool, TieredKvCache
    pool = PagePool(n_pages=8, page_size=4, n_kv=2, head_dim=8)
    tc = TieredKvCache(pool, high_wm=75.0, low_wm=40.0)
    tc.admit(1)
    tc.admit(2)
    k = np.ones((2, 8), np.float32)
    marker = {}
    for t in range(24):          # 6 pages for seq 1
        tc.append_token(1, k * t, k * (t + 100))
        marker[t] = t
    for t in range(16):          # 4 pages for seq 2 -> pool pressure
        tc.append_token(2, k * 50, k * 51)
    rep = tc.tier_report()
    assert rep["cold_pages"] > 0, "watermark eviction must have fired"
    # touching seq1 restores its pages with intact contents
    tc.page_table(1, 8)
    assert tc.restores > 0
    sp = tc.sequences[1]
    page0 = sp.page_ids[0]
    np.testing.assert_allclose(pool.k[page0, 1], k * 1)   # token t=1
    np.testing.assert_allclose(pool.v[page0, 3], k * 103)
    # O(1) per-sequence residency stats
    r = tc.residency_report(1)
    assert r and r[0]["count"] == 6
