from .sharding import MeshAxes, ShardingRules, profile_for
from .checkpoint import ArtifactStore, CheckpointManager
from .fault import HeartbeatMonitor, SimulatedFailure, run_with_restarts
from .elastic import reshard_state

__all__ = ["MeshAxes", "ShardingRules", "profile_for", "ArtifactStore",
           "CheckpointManager", "HeartbeatMonitor", "SimulatedFailure",
           "run_with_restarts", "reshard_state"]
