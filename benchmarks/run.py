"""Benchmark harness: one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (harness contract). Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]
                                            [--json OUT] [--trajectory DIR]

``--smoke`` shrinks problem sizes (CI budget: whole suite < 2 min);
``--json OUT`` additionally writes a BENCH_*.json-shaped dict for one run;
``--trajectory DIR`` *appends* each module's rows as a dated entry to
``DIR/BENCH_<module>.json`` (``bench_policy`` -> ``BENCH_policy.json``),
so numbers accumulate PR over PR and later PRs can diff against earlier
ones instead of starting an empty trajectory every time.
"""
from __future__ import annotations

import argparse
import datetime
import inspect
import json
import os
import sys
import time
import traceback

MODULES = [
    "bench_scan",        # Fig. 3: parallel DFS + multi-client scan
    "bench_changelog",   # SII-C2/SIII-A2: changelog rates, async dirty-tag
    "bench_stats",       # SII-B3: O(1) pre-aggregated reports
    "bench_policy",      # SII-B1: policy matching (4 evaluators + engine)
    "bench_find_du",     # SII-B4: find/du clones vs POSIX walk
    "bench_reports",     # PR6: mesh-resident reports vs host folds
    "bench_serving",     # PR7: multi-tenant scoped serving (perm bitmaps)
    "bench_tiering",     # PR8: out-of-core catalogs (warm-segment streaming)
    "bench_kvtier",      # adapted C7/C8: KV-page tiering + paged serving
    "bench_telemetry",   # PR9: registry/span overhead on warm hot paths
    "roofline_report",   # SRoofline summary rows from the dry-run artifacts
]


def _call_run(mod, smoke: bool) -> list:
    """Pass smoke= only to modules that accept it (older ones don't)."""
    sig = inspect.signature(mod.run)
    if "smoke" in sig.parameters:
        return mod.run(smoke=smoke)
    return mod.run()


def _append_trajectory(traj_dir: str, name: str, rows: list,
                       smoke: bool, elapsed_s: float,
                       short: str = None) -> str:
    """Append one dated entry to BENCH_<short>.json (atomic rewrite).

    ``short`` defaults to the module name minus its ``bench_`` prefix; a
    module may override it with a module-level ``TRAJECTORY`` attribute
    to append into another module's trajectory file (``bench_serving``
    extends ``BENCH_reports.json`` rather than starting a new table).
    """
    if short is None:
        short = name[len("bench_"):] if name.startswith("bench_") else name
    os.makedirs(traj_dir, exist_ok=True)
    path = os.path.join(traj_dir, f"BENCH_{short}.json")
    payload = {"suite": f"benchmarks.{name}", "entries": []}
    if os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                loaded = json.load(f)
            if isinstance(loaded.get("entries"), list):
                payload = loaded
        except (OSError, ValueError):
            pass                     # corrupt trajectory: restart it
    payload["entries"].append({
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "smoke": bool(smoke),
        "elapsed_s": round(elapsed_s, 3),
        "rows": [{"name": n, "us_per_call": float(us),
                  "derived": str(derived)} for n, us, derived in rows],
    })
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink sizes for a <2 min CI run")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="also write a BENCH_*.json-shaped result dict")
    ap.add_argument("--trajectory", default=None, metavar="DIR",
                    help="append each module's rows as a dated entry to "
                         "DIR/BENCH_<module>.json (perf trajectory over "
                         "PRs)")
    args = ap.parse_args()
    if args.only and args.only not in MODULES:
        ap.error(f"unknown module {args.only!r} (choose from {MODULES})")
    print("name,us_per_call,derived")
    failed = 0
    results = []
    t_start = time.time()
    for name in MODULES:
        if args.only and args.only != name:
            continue
        try:
            t_mod = time.time()
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = _call_run(mod, args.smoke)
            for row in rows:
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}", flush=True)
                results.append({"name": n, "us_per_call": float(us),
                                "derived": str(derived), "module": name})
            if args.trajectory:
                _append_trajectory(args.trajectory, name, rows,
                                   args.smoke, time.time() - t_mod,
                                   short=getattr(mod, "TRAJECTORY", None))
        except Exception as e:
            failed += 1
            print(f"{name},NaN,ERROR_{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.json_out:
        payload = {
            "suite": "benchmarks.run",
            "smoke": bool(args.smoke),
            "elapsed_s": round(time.time() - t_start, 3),
            "failed_modules": failed,
            "rows": results,
        }
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
