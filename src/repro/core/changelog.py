"""Transactional, persistent changelog streams — MDT ChangeLog analogue (C3).

Contract reproduced from the paper (SII-C2):

* records are appended to a per-MDT stream with monotonically increasing
  sequence numbers and kept on persistent storage;
* a consumer registers, reads batches, and **acks** a sequence number only
  after the corresponding change has been committed to its own database;
* records are purged only once acked, so no event is ever lost — even if the
  consumer crashes mid-processing, unacked records are re-delivered on
  restart.

A stream supports multiple named **subscribers**, each with its own read
cursor and ack watermark (Lustre's ``changelog_register`` users analogue):
the event pipeline mirrors records into the catalog under the default
subscriber while e.g. the policy engine follows the same stream under its
own cursor to maintain incremental match state. Records are purged only
once *every* subscriber has acked them.

Persistence is an append-only JSONL file per stream (fsync on append batch)
plus a tiny ack cursor file (an int for the lone default subscriber, a JSON
object once named subscribers exist). DNE is modelled by running one stream
per MDT.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from itertools import islice
from typing import Deque, Dict, Iterable, List, Optional

import numpy as np

from .telemetry import MetricRegistry
from .types import ChangelogRecord, ChangelogType

DEFAULT_SUBSCRIBER = "main"


class ColumnarRecords:
    """One read batch decoded into aligned numpy columns.

    The ingest hot path works on these arrays only — ``seq``/``fid``/
    ``type``/``time`` — so from the reader onward no per-event Python
    dict is ever built (the original :class:`ChangelogRecord` objects
    ride along solely for the per-record uid/jobid counters and the
    record-at-a-time differential oracle).
    """

    __slots__ = ("mdt", "seq", "fid", "type", "time", "records")

    def __init__(self, mdt: int, seq: np.ndarray, fid: np.ndarray,
                 type_: np.ndarray, time_: np.ndarray,
                 records: List[ChangelogRecord]) -> None:
        self.mdt = mdt
        self.seq = seq
        self.fid = fid
        self.type = type_
        self.time = time_
        self.records = records

    def __len__(self) -> int:
        return self.seq.shape[0]

    @classmethod
    def from_records(cls, recs: List[ChangelogRecord],
                     mdt: int) -> "ColumnarRecords":
        """Columnar decode: four vectorized passes, no per-event dicts."""
        n = len(recs)
        seq = np.fromiter((r.seq for r in recs), dtype=np.int64, count=n)
        fid = np.fromiter((r.fid for r in recs), dtype=np.int64, count=n)
        typ = np.fromiter((int(r.type) for r in recs), dtype=np.int8,
                          count=n)
        tim = np.fromiter((r.time for r in recs), dtype=np.float64, count=n)
        return cls(mdt, seq, fid, typ, tim, recs)


class _Subscriber:
    """Cursor/ack bookkeeping for one registered consumer."""

    __slots__ = ("name", "read_cursor", "acked", "durable")

    def __init__(self, name: str, read_cursor: int, acked: int,
                 durable: bool = True) -> None:
        self.name = name
        self.read_cursor = read_cursor
        self.acked = acked
        self.durable = durable


class ChangelogStream:
    """One MDT's changelog: producer side (append) + consumer side (read/ack)."""

    def __init__(self, mdt: int = 0, persist_dir: Optional[str] = None,
                 fsync: bool = False) -> None:
        self.mdt = mdt
        self._lock = threading.Condition()
        self._records: Deque[ChangelogRecord] = deque()
        self._next_seq = 1
        self._subs: Dict[str, _Subscriber] = {
            DEFAULT_SUBSCRIBER: _Subscriber(DEFAULT_SUBSCRIBER, 0, 0)
        }
        self._recovered_acks: Dict[str, int] = {}
        self._persist_dir = persist_dir
        self._fsync = fsync
        self._fh = None
        self._closed = False
        # telemetry (bind_telemetry): emitted-events counter + live
        # backlog/lag callback gauges; None until a pipeline (or caller)
        # binds a registry — emit stays a no-op-cost path until then
        self.telemetry: Optional[MetricRegistry] = None
        self._tclock = time.time
        self._emitted = None
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)
            self._log_path = os.path.join(persist_dir, f"changelog_mdt{mdt}.jsonl")
            self._ack_path = os.path.join(persist_dir, f"changelog_mdt{mdt}.ack")
            self._recover()
            self._fh = open(self._log_path, "a", encoding="utf-8")

    # -- persistence -----------------------------------------------------------
    def _recover(self) -> None:
        """Reload unacked records after a crash (paper: no event loss)."""
        acks: Dict[str, int] = {}
        if os.path.exists(self._ack_path):
            with open(self._ack_path, "r", encoding="utf-8") as f:
                txt = f.read().strip()
            if txt:
                try:
                    acks = {DEFAULT_SUBSCRIBER: int(txt)}
                except ValueError:
                    acks = {str(k): int(v) for k, v in json.loads(txt).items()}
        self._recovered_acks = acks
        acked = acks.get(DEFAULT_SUBSCRIBER, 0)
        main = self._subs[DEFAULT_SUBSCRIBER]
        main.acked = acked
        floor = min(acks.values()) if acks else 0
        if os.path.exists(self._log_path):
            with open(self._log_path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    d = json.loads(line)
                    rec = ChangelogRecord(
                        seq=d["seq"], type=ChangelogType(d["type"]),
                        fid=d["fid"], parent_fid=d.get("parent_fid", -1),
                        name=d.get("name", ""), time=d.get("time", 0.0),
                        uid=d.get("uid", ""), jobid=d.get("jobid", ""),
                        mdt=self.mdt, attrs=d.get("attrs"))
                    if rec.seq > floor:
                        self._records.append(rec)
                    self._next_seq = max(self._next_seq, rec.seq + 1)
        # re-delivery: each reader restarts from its oldest unacked record
        main.read_cursor = acked

    def _persist_records(self, recs: List[ChangelogRecord]) -> None:
        if self._fh is None:
            return
        for r in recs:
            self._fh.write(json.dumps({
                "seq": r.seq, "type": int(r.type), "fid": r.fid,
                "parent_fid": r.parent_fid, "name": r.name, "time": r.time,
                "uid": r.uid, "jobid": r.jobid, "attrs": r.attrs}) + "\n")
        self._fh.flush()
        if self._fsync:
            os.fsync(self._fh.fileno())

    def _persist_acks(self) -> None:
        if not self._persist_dir:
            return
        tmp = self._ack_path + ".tmp"
        acks = {name: ack for name, ack in self._recovered_acks.items()
                if name not in self._subs}      # not yet re-registered
        # ephemeral subscribers die with their process: persisting their ack
        # would pin the purge floor forever after a restart renames them
        acks.update({name: s.acked for name, s in self._subs.items()
                     if s.durable})
        with open(tmp, "w", encoding="utf-8") as f:
            if len(acks) == 1:
                f.write(str(acks[DEFAULT_SUBSCRIBER]))
            else:
                f.write(json.dumps(acks))
        os.replace(tmp, self._ack_path)

    # -- telemetry ---------------------------------------------------------------
    def bind_telemetry(self, registry: MetricRegistry,
                       clock=time.time) -> "ChangelogStream":
        """Land this stream's series in ``registry``: a
        ``changelog_events_emitted{mdt=}`` counter plus collection-time
        ``changelog_backlog`` / ``changelog_lag_seconds`` gauges, one
        series per subscriber — live cursor state read at scrape time,
        no write on the emit/ack hot paths. Idempotent per registry;
        an :class:`EventPipeline` binds its catalog's registry
        automatically."""
        if self.telemetry is registry:
            return self
        self.telemetry = registry
        self._tclock = clock
        self._emitted = registry.counter(
            "changelog_events_emitted", help="records appended to the MDT "
            "stream", mdt=str(self.mdt))
        mdt = str(self.mdt)
        registry.register_callback(
            f"changelog_backlog_mdt{self.mdt}",
            lambda: [({"mdt": mdt, "subscriber": name}, depth)
                     for name, depth in self._cursor_depths()],
            help="unacked records behind each subscriber cursor")
        registry.register_callback(
            f"changelog_lag_seconds_mdt{self.mdt}",
            lambda: [({"mdt": mdt, "subscriber": name},
                      self.lag_seconds(name))
                     for name in self.subscribers()],
            help="age of the oldest unacked record per subscriber")
        return self

    def _cursor_depths(self) -> List[tuple]:
        with self._lock:
            head = self._next_seq - 1
            return [(name, head - s.acked) for name, s in self._subs.items()]

    def backlog(self, subscriber: Optional[str] = None) -> int:
        """Alias of :meth:`pending` under the telemetry vocabulary."""
        return self.pending(subscriber)

    def lag_seconds(self, subscriber: Optional[str] = None) -> float:
        """Age of the subscriber's oldest unacked record (0.0 when fully
        caught up, or when records carry no timestamps)."""
        with self._lock:
            sub = self._sub(subscriber)
            if not self._records or self._records[-1].seq <= sub.acked:
                return 0.0
            idx = max(0, sub.acked - self._records[0].seq + 1)
            if idx >= len(self._records):
                return 0.0
            t = self._records[idx].time
            if not t:
                return 0.0
            return max(0.0, self._tclock() - t)

    # -- subscriber registry -----------------------------------------------------
    def subscribe(self, name: str, from_start: bool = False,
                  durable: bool = True) -> str:
        """Register a named consumer with its own read/ack cursor.

        A new subscriber starts at the stream head (future records only)
        unless ``from_start`` is set, in which case it sees every retained
        record. Re-subscribing an existing (or crash-recovered) name resumes
        from its persisted ack watermark. ``durable=False`` keeps the
        cursor out of the persisted ack file — for per-process consumers
        that rebuild their own state after a restart anyway — so a dead
        instance can never pin the purge floor. Returns the name.
        """
        if name == DEFAULT_SUBSCRIBER:
            return name
        with self._lock:
            if name in self._subs:
                return name
            if name in self._recovered_acks:
                start = self._recovered_acks.pop(name)   # resumed: consumed
            elif from_start:
                start = 0
            else:
                start = self._next_seq - 1
            self._subs[name] = _Subscriber(name, start, start,
                                           durable=durable)
            self._persist_acks()
            return name

    def unsubscribe(self, name: str) -> None:
        """Drop a named subscriber; records it held back become purgeable."""
        if name == DEFAULT_SUBSCRIBER:
            raise ValueError("cannot unsubscribe the default consumer")
        with self._lock:
            dropped = self._subs.pop(name, None) is not None
            # a crash-recovered ack must go too, or it would resurrect in
            # the ack file and pin the purge floor forever
            dropped |= self._recovered_acks.pop(name, None) is not None
            if dropped:
                self._purge()
                self._persist_acks()

    def subscribers(self) -> List[str]:
        with self._lock:
            return list(self._subs)

    def _sub(self, name: Optional[str]) -> _Subscriber:
        sub = self._subs.get(name or DEFAULT_SUBSCRIBER)
        if sub is None:
            raise KeyError(f"unknown changelog subscriber {name!r}")
        return sub

    # -- producer ----------------------------------------------------------------
    def emit(self, type: ChangelogType, fid: int, **kw) -> ChangelogRecord:
        with self._lock:
            rec = ChangelogRecord(seq=self._next_seq, type=type, fid=fid,
                                  mdt=self.mdt, **kw)
            self._next_seq += 1
            self._records.append(rec)
            self._persist_records([rec])
            if self._emitted is not None:
                self._emitted.inc()
            self._lock.notify_all()
            return rec

    def emit_batch(self, recs: Iterable[ChangelogRecord]) -> None:
        with self._lock:
            out = []
            for r in recs:
                r.seq = self._next_seq
                r.mdt = self.mdt
                self._next_seq += 1
                self._records.append(r)
                out.append(r)
            self._persist_records(out)
            if self._emitted is not None and out:
                self._emitted.inc(len(out))
            self._lock.notify_all()

    # -- consumer -----------------------------------------------------------------
    def read(self, max_records: int = 1024, timeout: Optional[float] = None,
             subscriber: Optional[str] = None,
             stop: Optional[threading.Event] = None) -> List[ChangelogRecord]:
        """Read the next batch past the subscriber's cursor (does NOT ack).

        Retained records are dense in seq and purged only from the front,
        so the cursor position is an index: a read costs O(position +
        batch), not O(backlog) — a lagging subscriber (e.g. an idle policy
        engine) cannot degrade the main consumer's read loop.

        ``timeout=None`` returns immediately when nothing is pending; pass
        a timeout (or ``float('inf')``-like large value) to block on the
        stream's condition variable until a record is emitted — no
        polling. A blocked read wakes on emit, :meth:`close`, :meth:`wake`,
        or when the optional ``stop`` event is set (checked only at wakeup
        — pair it with :meth:`wake` for prompt shutdown).
        """
        with self._lock:
            sub = self._sub(subscriber)
            if timeout is not None:
                self._lock.wait_for(
                    lambda: self._closed
                    or (stop is not None and stop.is_set())
                    or (self._records
                        and self._records[-1].seq > sub.read_cursor),
                    timeout=timeout)
            if not self._records or self._records[-1].seq <= sub.read_cursor:
                return []
            start = max(0, sub.read_cursor - self._records[0].seq + 1)
            out = list(islice(self._records, start, start + max_records))
            if out:
                sub.read_cursor = out[-1].seq
            return out

    def read_columnar(self, max_records: int = 1024,
                      timeout: Optional[float] = None,
                      subscriber: Optional[str] = None,
                      stop: Optional[threading.Event] = None
                      ) -> Optional[ColumnarRecords]:
        """:meth:`read`, decoded to a :class:`ColumnarRecords` batch.

        Returns ``None`` instead of an empty batch so callers can
        distinguish 'nothing pending' without touching numpy.
        """
        recs = self.read(max_records=max_records, timeout=timeout,
                         subscriber=subscriber, stop=stop)
        if not recs:
            return None
        return ColumnarRecords.from_records(recs, self.mdt)

    def wake(self) -> None:
        """Wake every blocked :meth:`read` (shutdown path: set the stop
        event the readers were given, then call this)."""
        with self._lock:
            self._lock.notify_all()

    @property
    def acked(self) -> int:
        """Highest acknowledged sequence number (default consumer)."""
        with self._lock:
            return self._subs[DEFAULT_SUBSCRIBER].acked

    def acked_of(self, subscriber: str) -> int:
        with self._lock:
            return self._sub(subscriber).acked

    def _purge(self) -> None:
        floor = min(s.acked for s in self._subs.values())
        for name, ack in self._recovered_acks.items():
            if name not in self._subs:          # crashed subscriber, not back yet
                floor = min(floor, ack)
        while self._records and self._records[0].seq <= floor:
            self._records.popleft()

    def ack(self, seq: int, subscriber: Optional[str] = None) -> None:
        """Acknowledge records up to ``seq`` for one subscriber; records are
        purged once every subscriber has acked them."""
        with self._lock:
            sub = self._sub(subscriber)
            # clamp to emitted seqs: acking past the head must not swallow
            # records emitted later
            sub.acked = min(max(sub.acked, seq), self._next_seq - 1)
            sub.read_cursor = max(sub.read_cursor, sub.acked)
            self._purge()
            self._persist_acks()

    def reset_cursor(self, subscriber: Optional[str] = None) -> None:
        """Simulate consumer restart: unacked records are re-delivered."""
        with self._lock:
            sub = self._sub(subscriber)
            sub.read_cursor = sub.acked

    def pending(self, subscriber: Optional[str] = None) -> int:
        """Unacked record count — O(1): seqs are dense and retention always
        covers (purge floor, head] ⊇ (acked, head]."""
        with self._lock:
            sub = self._sub(subscriber)
            return self._next_seq - 1 - sub.acked

    def close(self) -> None:
        """Close the stream (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            self._lock.notify_all()


class ChangelogHub:
    """All MDT streams of a (possibly DNE) filesystem."""

    def __init__(self, n_mdts: int = 1, persist_dir: Optional[str] = None,
                 fsync: bool = False) -> None:
        self.streams: Dict[int, ChangelogStream] = {
            i: ChangelogStream(i, persist_dir, fsync) for i in range(n_mdts)
        }
        self._rr = 0          # rotating round-robin start cursor
        self._closed = False

    def stream(self, mdt: int = 0) -> ChangelogStream:
        return self.streams[mdt]

    def subscribe(self, name: str, from_start: bool = False) -> str:
        """Register ``name`` on every MDT stream."""
        for s in self.streams.values():
            s.subscribe(name, from_start=from_start)
        return name

    def total_pending(self) -> int:
        return sum(s.pending() for s in self.streams.values())

    def read_round_robin(self, quantum: int = 1024,
                         subscriber: Optional[str] = None
                         ) -> List[ColumnarRecords]:
        """One fair sweep over every MDT stream: up to ``quantum`` records
        from each, visiting streams in rotating order so a storming MDT
        can never starve the others — per sweep, every stream with
        pending records contributes a batch, so a quiet stream's lag is
        bounded by one quantum regardless of how deep another stream's
        backlog grows. Returns the non-empty batches in visit order.
        """
        mdts = sorted(self.streams)
        n = len(mdts)
        start = self._rr % n if n else 0
        self._rr += 1
        out: List[ColumnarRecords] = []
        for i in range(n):
            s = self.streams[mdts[(start + i) % n]]
            cb = s.read_columnar(max_records=quantum, subscriber=subscriber)
            if cb is not None:
                out.append(cb)
        return out

    def wake(self) -> None:
        for s in self.streams.values():
            s.wake()

    def close(self) -> None:
        """Close every stream (idempotent — safe to call more than once)."""
        if self._closed:
            return
        self._closed = True
        for s in self.streams.values():
            s.close()
