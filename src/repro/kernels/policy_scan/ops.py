"""Public policy-scan op: pads, dispatches kernel/oracle, unpads."""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANE, policy_scan_batch_pallas, policy_scan_pallas
from .ref import (N_AGG, policy_scan_batch_ref, policy_scan_multi_ref,
                  policy_scan_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                operands: jax.Array, size_col: int = 0, blocks_col: int = 1,
                valid_col: int = -1, use_kernel: bool = True,
                tile: int = 8 * LANE) -> Tuple[jax.Array, jax.Array]:
    """Evaluate a predicate program over a columnar table + aggregates.

    cols: (n_cols, N) f32. Returns (mask (N,) f32, agg (N_AGG,) f32).
    Rows are padded to the tile size with an all-invalid pad (mask forced 0
    via a validity column the wrapper appends when ``valid_col`` < 0).
    """
    n_cols, n = cols.shape
    if n == 0:            # zero-row table: nothing to scan (grid would be 0)
        return jnp.zeros((0,), jnp.float32), jnp.zeros((N_AGG,), jnp.float32)
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    mask, agg = policy_scan_pallas(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col, tile=tile,
        interpret=not _on_tpu()) if use_kernel else policy_scan_ref(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col)
    return mask[:n], agg


@partial(jax.jit, static_argnames=("size_col", "blocks_col"))
def policy_scan_multi(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                      operands: jax.Array, size_col: int = 0,
                      blocks_col: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Evaluate R padded predicate programs over one column stack.

    cols: (n_cols, N) f32; ops/colidx/operands: (R, P), OP_NOP padded.
    Returns (masks (R, N) f32, agg (N_AGG,) f32 for program 0). One
    columnar pass: matching and size/blocks aggregation fuse in one scan.
    """
    return policy_scan_multi_ref(cols, ops.astype(jnp.int32),
                                 colidx.astype(jnp.int32),
                                 operands.astype(jnp.float32),
                                 size_col=size_col, blocks_col=blocks_col)


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan_batch(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                      operands: jax.Array, size_col: int = 0,
                      blocks_col: int = 1, valid_col: int = -1,
                      use_kernel: bool = True, tile: int = 8 * LANE
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-launch batch matcher over a columnar table.

    cols: (n_cols, N) f32; ops/colidx/operands: (R, P) OP_NOP-padded
    programs (program 0 = combined criteria, 1..R-1 = per-rule conditions).
    Returns (masks (R, N) f32, rule_idx (N,) i32, agg (R, N_AGG) f32): all
    program masks, fused first-match-wins attribution, and per-program
    size/blocks reductions — one kernel launch instead of R.
    """
    n_cols, n = cols.shape
    if n == 0:            # zero-row table: nothing to scan (grid would be 0)
        r = ops.shape[0]
        return (jnp.zeros((r, 0), jnp.float32), jnp.zeros((0,), jnp.int32),
                jnp.zeros((r, N_AGG), jnp.float32))
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    args = (cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
            operands.astype(jnp.float32))
    kw = dict(size_col=size_col, blocks_col=blocks_col, valid_col=valid_col)
    if use_kernel:
        masks, rule, agg = policy_scan_batch_pallas(
            *args, tile=tile, interpret=not _on_tpu(), **kw)
    else:
        masks, rule, agg = policy_scan_batch_ref(*args, **kw)
    return masks[:, :n], rule[:n], agg


def column_stack(arrays) -> jax.Array:
    """Stack a Catalog.arrays() dict into the (n_cols, N) f32 kernel layout."""
    from ...core.policy import KERNEL_COLUMNS
    return jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)


def _attribute_np(masks: List[np.ndarray]) -> np.ndarray:
    """Host-side first-match-wins attribution (per-rule-launch fallback):
    ``masks[0]`` is the combined criteria (excluded), ``masks[1:]`` the
    rules. Delegates to the single semantics authority in core.policy."""
    from ...core.policy import attribute_rules
    n = masks[0].shape[0] if masks else 0
    return attribute_rules(masks[1:], n)


def _agg_dict(agg_np: np.ndarray, per_rule: Optional[np.ndarray] = None
              ) -> dict:
    out = {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
    if per_rule is not None and per_rule.shape[0] > 1:
        out["rule_count"] = per_rule[1:, 0].tolist()
        out["rule_volume"] = per_rule[1:, 1].tolist()
        out["rule_spc_used"] = per_rule[1:, 2].tolist()
    return out


def match_programs(arrays, exprs, strings, now: float,
                   use_kernel: Optional[bool] = None,
                   single_launch: Optional[bool] = None
                   ) -> Tuple[List[np.ndarray], dict, np.ndarray]:
    """Evaluate several core.policy Exprs over catalog columns at once.

    ``exprs[0]`` is the combined match criteria (its fused aggregates are
    returned); further exprs are per-rule conditions in priority order.
    Returns ``(masks, agg, rule_idx)``: one boolean mask per program, the
    aggregate dict of program 0 (plus ``rule_count``/``rule_volume``/
    ``rule_spc_used`` per-rule reductions when rules are present), and the
    (N,) int32 first-match-wins rule attribution (-1 = no rule).

    ``use_kernel=None`` selects the Pallas kernel on TPU and the jitted
    oracle everywhere else. ``single_launch`` (default True) evaluates the
    whole (R, P) program batch in ONE launch with attribution and per-rule
    reductions fused on-device; ``single_launch=False`` keeps the legacy
    one-launch-per-program path as a fallback and differential oracle.
    Raises PolicyError if any expr contains host-only (glob) predicates —
    callers fall back to the numpy mask path.
    """
    from ...core.policy import KERNEL_COLUMNS, compile_programs
    ops, colidx, operands = compile_programs(exprs, strings, now)
    kcols = column_stack(arrays)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    if use_kernel is None:
        use_kernel = _on_tpu()
    if single_launch is None:
        single_launch = True
    if single_launch:
        m, rule, agg = policy_scan_batch(
            kcols, jnp.asarray(ops), jnp.asarray(colidx),
            jnp.asarray(operands), size_col=size_col, blocks_col=blocks_col,
            use_kernel=use_kernel)
        m = np.asarray(m) > 0.5
        masks = [m[r] for r in range(m.shape[0])]
        per_rule = np.asarray(agg)
        return masks, _agg_dict(per_rule[0], per_rule), \
            np.asarray(rule, dtype=np.int32)
    # Fallback: one launch per program (program 0 still fuses mask +
    # aggregation in a single HBM pass; rule programs reuse the resident
    # column stack), attribution on the host.
    masks, aggs = [], []
    for r in range(ops.shape[0]):
        m, a = policy_scan(kcols, jnp.asarray(ops[r]),
                           jnp.asarray(colidx[r]),
                           jnp.asarray(operands[r]), size_col=size_col,
                           blocks_col=blocks_col, use_kernel=use_kernel)
        aggs.append(np.asarray(a))
        masks.append(np.asarray(m) > 0.5)
    per_rule = np.stack(aggs)
    return masks, _agg_dict(per_rule[0], per_rule), _attribute_np(masks)


def scan_catalog(catalog, expr, now: float, use_kernel: bool = True
                 ) -> Tuple[np.ndarray, dict]:
    """Run a core.policy expression over a Catalog via the kernel path.

    Only numeric/categorical predicates compile to the kernel program;
    glob predicates raise PolicyError (callers fall back to Expr.mask).
    Returns (matching fids, aggregate dict).
    """
    from ...core.policy import KERNEL_COLUMNS, compile_program
    arrays = catalog.arrays()
    ops, colidx, operands = compile_program(expr, catalog.strings, now)
    cols = jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    mask, agg = policy_scan(cols, jnp.asarray(ops), jnp.asarray(colidx),
                            jnp.asarray(operands), size_col=size_col,
                            blocks_col=blocks_col, use_kernel=use_kernel)
    mask_np = np.asarray(mask) > 0.5
    agg_np = np.asarray(agg)
    return arrays["fid"][mask_np], {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
