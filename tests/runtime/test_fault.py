"""Failure detection, checkpoint/restart, straggler mitigation."""
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import (HeartbeatMonitor, RedundantShardRouter,
                                 SimulatedFailure, run_with_restarts)


def test_heartbeat_detects_dead_hosts(fake_clock):
    hb = HeartbeatMonitor(n_hosts=4, timeout=5.0, clock=fake_clock)
    assert hb.healthy()
    fake_clock.advance(3)
    for h in (0, 1, 2):
        hb.beat(h)
    fake_clock.advance(3)
    assert hb.dead_hosts() == [3]
    hb.revive(3)
    assert hb.healthy()
    hb.mark_dead(1)
    assert 1 in hb.dead_hosts()


def test_run_with_restarts_completes(tmp_path):
    """Inject failures at fixed steps; training must still finish exactly."""
    import jax.numpy as jnp
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=3)
    failures = {7, 23}
    seen = []

    def init_state():
        return {"acc": jnp.zeros(()), "hist": jnp.zeros(40)}

    def step_fn(state, step):
        if step in failures:
            failures.discard(step)
            raise SimulatedFailure(host=step % 4, step=step)
        seen.append(step)
        return {"acc": state["acc"] + step,
                "hist": state["hist"].at[step].set(1.0)}

    final, restarts, replayed = run_with_restarts(
        train_steps=30, step_fn=step_fn, init_state=init_state, ckpt=cm,
        ckpt_interval=5)
    assert restarts == 2 and replayed > 0
    # the final accumulator must equal an exact, single-pass run
    assert float(final["acc"]) == sum(range(30))
    assert float(final["hist"].sum()) == 30


def test_restart_budget_enforced(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"))

    def step_fn(state, step):
        raise SimulatedFailure(host=0, step=step)

    with pytest.raises(RuntimeError, match="restart budget"):
        run_with_restarts(5, step_fn, lambda: {"x": np.zeros(1)}, cm,
                          max_restarts=2)


def test_redundant_shards_cover_failures():
    r = RedundantShardRouter(n_shards=16, n_hosts=8, replication=2)
    assert r.coverage_without([]) == 1.0
    assert r.coverage_without([3]) == 1.0          # any single host loss
    # replication=2 with adjacent assignment: losing 2 adjacent hosts
    # may drop shards; coverage reports it honestly
    cov = r.coverage_without([0, 1])
    assert 0.8 <= cov <= 1.0


def test_straggler_picks_fast_replica():
    r = RedundantShardRouter(n_shards=4, n_hosts=4, replication=2)
    latency = {0: 10.0, 1: 0.1, 2: 10.0, 3: 0.1}
    for s in range(4):
        picked = r.pick(s, lambda h: latency[h])
        assert latency[picked] <= min(latency[h] for h in r.hosts_for(s))
