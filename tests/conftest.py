"""Test fixtures. NOTE: device count stays 1 here — only launch/dryrun.py
forces 512 fake devices; multi-device tests spawn subprocesses."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture
def fake_clock():
    class Clock:
        def __init__(self):
            self.t = 1_000_000.0

        def __call__(self):
            return self.t

        def advance(self, dt):
            self.t += dt
    return Clock()


def run_subprocess(code: str, devices: int = 8, timeout: int = 300) -> str:
    """Run python code in a subprocess with N fake XLA devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout
