"""Policy language: parser + three-evaluator equivalence (hypothesis)."""
import time

import numpy as np
import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, never hard-error collection
from hypothesis import given, settings, strategies as st

from repro.core import Catalog, Entry, FsType, parse_expr, PolicyError
from repro.core.policy import (KERNEL_COLUMNS, compile_program)
from repro.core.types import parse_size

NOW = 2_000_000.0


def test_paper_example_parses():
    e = parse_expr("(size > 1GB or owner == 'foo') "
                   "and path == '/my/fs/*.tar'")
    ent = dict(size=2 << 30, owner="bar", path="/my/fs/x.tar")
    assert e.evaluate(ent, NOW)
    ent2 = dict(size=10, owner="foo", path="/my/fs/y.tar")
    assert e.evaluate(ent2, NOW)
    ent3 = dict(size=10, owner="baz", path="/my/fs/y.tar")
    assert not e.evaluate(ent3, NOW)


def test_units_and_ages():
    assert parse_size("1GB") == 1 << 30
    assert parse_size("512k") == 512 << 10
    e = parse_expr("last_access > 1d")
    assert e.evaluate(dict(atime=NOW - 90000), NOW)
    assert not e.evaluate(dict(atime=NOW - 100), NOW)


def test_type_and_hsm_literals():
    e = parse_expr("type == dir and hsm_state == released")
    from repro.core import HsmState
    assert e.evaluate(dict(type=FsType.DIR, hsm_state=HsmState.RELEASED), NOW)


def test_parse_errors():
    for bad in ("size >", "and size > 1", "size >> 3", "(size > 1"):
        with pytest.raises(PolicyError):
            parse_expr(bad)


# -- hypothesis: random expressions agree across all evaluators --------------

_num_attr = st.sampled_from(["size", "blocks", "nlink"])
_cat_attr = st.sampled_from(["owner", "group"])
_op = st.sampled_from(["==", "!=", ">", ">=", "<", "<="])
_names = ["foo", "bar", "baz"]


def _leaf():
    num = st.builds(lambda a, o, v: f"{a} {o} {v}", _num_attr, _op,
                    st.integers(0, 10000))
    cat = st.builds(lambda a, o, v: f"{a} {o} '{v}'", _cat_attr,
                    st.sampled_from(["==", "!="]), st.sampled_from(_names))
    return st.one_of(num, cat)


def _expr(depth=2):
    if depth == 0:
        return _leaf()
    sub = _expr(depth - 1)
    return st.one_of(
        _leaf(),
        st.builds(lambda a, b: f"({a} and {b})", sub, sub),
        st.builds(lambda a, b: f"({a} or {b})", sub, sub),
        st.builds(lambda a: f"not ({a})", sub),
    )


@settings(max_examples=40, deadline=None)
@given(text=_expr(), seed=st.integers(0, 99))
def test_evaluator_equivalence(text, seed):
    rng = np.random.default_rng(seed)
    cat = Catalog(n_shards=2)
    for fid in range(1, 41):
        cat.upsert(Entry(
            fid=fid, name=f"f{fid}", path=f"/x/f{fid}", type=FsType.FILE,
            size=int(rng.integers(0, 12000)),
            blocks=int(rng.integers(0, 12000)),
            nlink=int(rng.integers(1, 5)),
            owner=_names[rng.integers(0, 3)],
            group=_names[rng.integers(0, 3)],
            atime=NOW - 10, mtime=NOW - 10, ctime=NOW - 10))
    expr = parse_expr(text)
    cols = cat.arrays()
    vec = expr.mask(cols, cat.strings, NOW)
    # per-entry evaluation
    by_fid = {int(f): m for f, m in zip(cols["fid"], vec)}
    for e in cat.entries():
        assert expr.evaluate(e, NOW) == bool(by_fid[e.fid]), text
    # kernel program (pure-jnp oracle path)
    from repro.kernels.policy_scan.ref import eval_program
    import jax.numpy as jnp
    ops, ci, opr = compile_program(expr, cat.strings, NOW)
    kcols = jnp.stack([jnp.asarray(cols[c], jnp.float32)
                       for c in KERNEL_COLUMNS])
    kmask = np.asarray(eval_program(kcols, jnp.asarray(ops),
                                    jnp.asarray(ci), jnp.asarray(opr)))
    np.testing.assert_array_equal(kmask > 0.5, vec, err_msg=text)
