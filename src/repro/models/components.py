"""Model building blocks, pure JAX (jax.lax control flow only).

Numerics policy: parameters and activations are bf16; softmax, norms, and
recurrences accumulate in f32. Attention is a chunked online-softmax
(flash-style) implementation so 32k prefill never materializes (Sq, Sk)
score matrices; RWKV6 uses the chunked linear-attention form with all decay
exponents clamped ≤ 0 (provably safe — see tests/models/test_rwkv_ref.py).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import MoeSpec

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return ((1.0 + w.astype(jnp.float32)) * out).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (w.astype(jnp.float32) * out + b.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]    # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x_rot[..., :half].astype(jnp.float32), x_rot[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention (chunked online softmax; GQA; sliding window; softcap)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, kv_pos: jax.Array,
              causal: bool = True, window: int = 0,
              logit_softcap: Optional[float] = None,
              kv_chunk: int = 1024, unroll: int = 1) -> jax.Array:
    """Memory-bounded attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H = K * G.
    q_pos: (Sq,) absolute positions; kv_pos: (Sk,) absolute positions, -1
    marks invalid cache slots. Never materializes more than (.., Sq, chunk)
    scores.

    GQA k/v are broadcast to H heads up front so the head axis — the TP
    sharding axis — stays intact through every einsum (a (K, G) split of a
    sharded H would force GSPMD to all-gather).
    """
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale

    def block(kc, kp):
        """Masked scores for one kv chunk: (B, H, Sq, C)."""
        s = jnp.einsum("bqhd,bchd->bhqc", qf, kc.astype(jnp.float32))
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        m = kp[None, :] >= 0
        if causal:
            m = m & (kp[None, :] <= q_pos[:, None])
        if window:
            m = m & (kp[None, :] > q_pos[:, None] - window)
        return jnp.where(m[None, None, :, :], s, _NEG_INF)

    if Sk <= kv_chunk or Sk % kv_chunk != 0:
        # direct path (also the fallback for non-divisible small shapes)
        s = block(k, kv_pos)
        mx = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - mx)
        p = jnp.where(s > 0.5 * _NEG_INF, p, 0.0)   # fully-masked guard
        denom = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqc,bchd->bqhd", p, v.astype(jnp.float32))
        o = o / jnp.maximum(denom, 1e-20).transpose(0, 2, 1, 3)
        return o.astype(q.dtype)

    n = Sk // kv_chunk
    ks = k.reshape(B, n, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kps = kv_pos.reshape(n, kv_chunk)

    def body(carry, inp):
        m_run, l_run, acc = carry
        kc, vc, kp = inp
        s = block(kc, kp)                            # (B,H,Sq,C)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(s > 0.5 * _NEG_INF, p, 0.0)   # fully-masked guard
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), dtype=jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps),
                                      unroll=unroll)
    out = acc / jnp.maximum(l_f, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array
           ) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
             b2: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w1 + b1)
    return h @ w2 + b2


def gelu_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# Mixture of Experts — capacity-based sort-free dispatch
# ---------------------------------------------------------------------------

def _positions_in_expert(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each routed token within its expert, via one sort.

    Avoids the (T*k, E) one-hot cumsum (O(T*E) memory); this is O(T log T)
    and keeps peak memory at O(T).
    """
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts),
                                 side="left")
    pos_sorted = jnp.arange(tk) - seg_start[sorted_e]
    return jnp.zeros(tk, dtype=jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))


def moe_forward(x: jax.Array, router_w: jax.Array, w1: jax.Array,
                w3: jax.Array, w2: jax.Array, moe: MoeSpec,
                shared: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
                groups: int = 1, buf_pspec=None) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with capacity-factor dispatch (tokens over capacity drop).

    x: (B, S, D); router_w: (D, E); experts w1/w3: (E, D, F), w2: (E, F, D).
    Returns (out, aux_loss).

    ``groups``: dispatch-group count. With groups == the data-parallel
    degree, the token->capacity scatter becomes a *batched* scatter whose
    leading dim aligns with the batch sharding, so GSPMD partitions it
    locally — a global scatter forces full replication of the (E, cap, D)
    buffer + giant all-reduces (measured in EXPERIMENTS.md SPerf: 19
    all-reduces / 90 GB per layer -> gone).
    """
    B, S, D = x.shape
    E, k = moe.num_experts, moe.top_k
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ router_w).astype(jnp.float32)          # (T, E)
    top_logits, top_idx = jax.lax.top_k(logits, k)        # (T, k)
    if k == 1:
        weights = jax.nn.sigmoid(top_logits)              # llama4-style
    else:
        weights = jax.nn.softmax(top_logits, axis=-1)     # mixtral-style

    # load-balancing aux loss (Switch/Mixtral form)
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(probs, axis=0)                     # (E,)
    usage = jnp.mean(
        (jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32)), axis=0)
    aux = E * jnp.sum(density * usage)

    G = groups if T % groups == 0 else 1
    Tg = T // G
    cap = int(math.ceil(moe.capacity_factor * Tg * k / E))
    cap = max(8, (cap + 7) // 8 * 8)

    flat_e = top_idx.reshape(G, Tg * k)
    pos = jax.vmap(lambda fe: _positions_in_expert(fe, E))(flat_e)
    keep = (pos < cap)
    pos_c = jnp.minimum(pos, cap - 1)

    xg = jnp.repeat(xt.reshape(G, Tg, D), k, axis=1)       # (G, Tg*k, D)
    contrib = jnp.where(keep[..., None], xg, 0)
    buf = jax.vmap(
        lambda fe, pc, c: jnp.zeros((E, cap, D), dtype=x.dtype)
        .at[fe, pc].add(c))(flat_e, pos_c, contrib)        # (G, E, cap, D)

    def pin(t):
        """Keep the group dim data-sharded (GSPMD otherwise replicates it
        to feed the expert contraction — 20 GB/layer all-reduces, SPerf)."""
        if buf_pspec is None:
            return t
        return jax.lax.with_sharding_constraint(t, buf_pspec)

    buf = pin(buf)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w1)) * \
        jnp.einsum("gecd,edf->gecf", buf, w3)
    y = pin(jnp.einsum("gecf,efd->gecd", h, w2))           # (G, E, cap, D)

    gathered = jax.vmap(lambda yg, fe, pc: yg[fe, pc])(y, flat_e, pos_c)
    wk = (weights.reshape(G, Tg * k, 1) * keep[..., None]).astype(x.dtype)
    out = (gathered * wk).reshape(G, Tg, k, D).sum(axis=2)

    out = out.reshape(T, D)
    if shared is not None:
        s1, s3, s2 = shared
        out = out + swiglu(xt, s1, s3, s2)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def _rglru_gates(v: jax.Array, p: dict) -> Tuple[jax.Array, jax.Array]:
    """log_a (decay, in log space, <= 0) and gated input, both f32."""
    vf = v.astype(jnp.float32)
    r = jax.nn.sigmoid(vf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(vf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])      # (.., R) <= 0
    gated = i * vf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * gated


def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal temporal conv. x: (B,S,R); w: (width,R).

    Returns (y, new_state) where state carries the trailing (width-1) inputs.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), dtype=x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


def rglru_scan(log_a: jax.Array, b: jax.Array,
               h0: Optional[jax.Array] = None) -> jax.Array:
    """Diagonal linear recurrence h_t = exp(log_a_t) h_{t-1} + b_t.

    Uses an associative scan (log-depth on TPU). log_a, b: (B, S, R) f32.
    """
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b2 + jnp.exp(a2) * b1

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_step(log_a: jax.Array, b: jax.Array, h: jax.Array) -> jax.Array:
    """One decode step: (B, R) each."""
    return jnp.exp(log_a) * h + b
