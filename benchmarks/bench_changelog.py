"""Paper SII-C2 + SIII-A2: changelog processing rate, sync vs async
dirty-tag (the paper's proposed improvement, implemented), and vs rescan.

Ingest rates are reported both as wall-clock measurements and as the
registry's own ``pipeline_events_folded`` counter delta, and each run
samples the stream's backlog/lag gauges before and after the drain — the
same numbers an external scrape of ``render_prometheus()`` sees, so the
bench doubles as a check that the telemetry plane tracks reality.
"""
from __future__ import annotations

import time

from repro.core import Catalog, EventPipeline, PipelineConfig, Scanner
from repro.fs import LustreSim


def _workload(n_files=800, updates_per_file=5):
    fs = LustreSim()
    d = fs.mkdir(fs.root_fid(), "hot")
    fids = [fs.create(d, f"f{i}", owner="u") for i in range(n_files)]
    # drain creation events first
    cat = Catalog()
    EventPipeline(fs, cat, fs.changelog.stream(0),
                  PipelineConfig()).process_once(10 ** 6)
    # hot-file workload: repeated writes (dedup-friendly, paper SIII-A2)
    for r in range(updates_per_file):
        for f in fids:
            fs.write(f, 100)
    return fs, cat, n_files * updates_per_file


def _folded(cat) -> float:
    return sum(v for k, v in cat.telemetry.counter_values().items()
               if k.startswith("pipeline_events_folded"))


def run() -> list:
    rows = []
    for mode in ("sync", "async_dirty_tag"):
        fs, cat, n_events = _workload()
        cfg = PipelineConfig(async_updates=(mode != "sync"), batch_size=512)
        stream = fs.changelog.stream(0)
        pipe = EventPipeline(fs, cat, stream, cfg)
        backlog0, lag0 = stream.backlog(), stream.lag_seconds()
        folded0 = _folded(cat)
        t0 = time.perf_counter()
        n = pipe.process_once(10 ** 7)
        dt = time.perf_counter() - t0
        extra = f"_dedup_{pipe.dedup_hits}" if mode != "sync" else ""
        rows.append((f"changelog_{mode}", 1e6 * dt / max(1, n),
                     f"{n/dt:.0f}_records_per_s{extra}"))
        folded_rate = (_folded(cat) - folded0) / dt
        assert stream.backlog() == 0 and stream.lag_seconds() == 0.0, \
            "drain left the backlog/lag gauges non-zero"
        rows.append((f"changelog_{mode}_telemetry", 1e6 * dt / max(1, n),
                     f"{folded_rate:.0f}_events_folded_per_s_backlog_"
                     f"{backlog0}to0_lag_{lag0:.3f}s_to0"))
    # the alternative the paper kills: full rescan to refresh the mirror
    fs, cat, _ = _workload()
    t0 = time.perf_counter()
    Scanner(fs, cat, n_threads=4).scan()
    dt = time.perf_counter() - t0
    rows.append(("full_rescan_equivalent", 1e6 * dt / fs.count(),
                 f"{fs.count()/dt:.0f}_entries_per_s"))
    return rows
