"""Pallas TPU kernel: fused profile-cube segment reduction.

One grid walk over the columnar entry table replaces the scalar
``StatsAggregator`` fold (one python dict update per entry per report
dimension): each grid step holds a (n_cols, tile) block in VMEM,
bucketizes the tile's rows on-device (log-size bucket from static edges,
age bucket from ``now - atime`` ages precomputed on the host), and
accumulates the (B, S*A) segment sums for the three measures through the
MXU — the segment reduction is expressed as two one-hot matmuls
(``G (B, tile) @ SA (tile, S*A)``), the standard TPU scatter-add idiom.

The cube accumulator block (3*B, S*A) is revisited by every grid step
(standard Pallas reduction pattern): rows [0, B) are counts, [B, 2B)
volumes, [2B, 3B) spc_used.

VMEM budget: the gid one-hot is (B, tile) f32 — with the default
``tile=1024`` that is 4 MB at B=1024, so the op wrapper caps the group
axis (callers with more distinct (owner, group, type, hsm) combinations
fall back to the host groupby path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (AGE_EDGE_VALS, A_BUCKETS, N_MEASURES, SIZE_EDGE_VALS,
                  S_BUCKETS)

LANE = 128


def _profile_cube_kernel(cols_ref, cube_ref, *, n_groups: int, gid_col: int,
                         size_col: int, blocks_col: int, age_col: int,
                         valid_col: int, sb_col: int, ab_col: int):
    step = pl.program_id(0)
    cols = cols_ref[...]                      # (n_cols, tile) f32 in VMEM
    tile = cols.shape[1]

    gid = cols[gid_col]
    size = cols[size_col]
    blocks = cols[blocks_col]
    age = cols[age_col]
    valid = cols[valid_col] if valid_col >= 0 \
        else jnp.ones((tile,), jnp.float32)

    # --- bucketization ----------------------------------------------------
    # fused on-device from raw size/age, or taken from precomputed bucket
    # columns (exact host bucketization: raw values near a bucket edge
    # can round across it under the f32 cast; small indices are exact)
    if sb_col >= 0:
        sb = cols[sb_col].astype(jnp.int32)
    else:
        sb = sum((size >= e).astype(jnp.int32) for e in SIZE_EDGE_VALS) - 1
    sb = jnp.clip(sb, 0, S_BUCKETS - 1)
    if ab_col >= 0:
        ab = cols[ab_col].astype(jnp.int32)
    else:
        ab = sum((age >= e).astype(jnp.int32) for e in AGE_EDGE_VALS) - 1
    ab = jnp.clip(ab, 0, A_BUCKETS - 1)
    sa = sb * A_BUCKETS + ab                  # (tile,) i32

    # --- one-hot segment reduction through the MXU ------------------------
    iota_b = jax.lax.broadcasted_iota(jnp.float32, (n_groups, tile), 0)
    onehot_g = (gid[None, :] == iota_b).astype(jnp.float32) \
        * valid[None, :]                      # (B, tile)
    n_sa = S_BUCKETS * A_BUCKETS
    iota_sa = jax.lax.broadcasted_iota(jnp.int32, (n_sa, tile), 0)
    onehot_sa = (sa[None, :] == iota_sa).astype(jnp.float32)   # (SA, tile)

    dot = functools.partial(jax.lax.dot_general,
                            dimension_numbers=(((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    count = dot(onehot_g, onehot_sa)                          # (B, SA)
    volume = dot(onehot_g * size[None, :], onehot_sa)         # (B, SA)
    spc = dot(onehot_g * blocks[None, :], onehot_sa)          # (B, SA)
    cube = jnp.concatenate([count, volume, spc], axis=0)      # (3B, SA)

    @pl.when(step == 0)
    def _init():
        cube_ref[...] = jnp.zeros_like(cube_ref)

    cube_ref[...] += cube


def profile_cube_pallas(cols: jax.Array, *, n_groups: int, gid_col: int = 0,
                        size_col: int = 1, blocks_col: int = 2,
                        age_col: int = 3, valid_col: int = -1,
                        sb_col: int = -1, ab_col: int = -1,
                        tile: int = 8 * LANE, interpret: bool = True
                        ) -> jax.Array:
    """cols: (n_cols, N) f32, N % tile == 0. Returns the
    (N_MEASURES * n_groups, S_BUCKETS * A_BUCKETS) f32 cube."""
    n_cols, n = cols.shape
    assert n % tile == 0, f"N={n} must be padded to tile={tile}"
    grid = (n // tile,)
    n_sa = S_BUCKETS * A_BUCKETS

    kernel = functools.partial(
        _profile_cube_kernel, n_groups=n_groups, gid_col=gid_col,
        size_col=size_col, blocks_col=blocks_col, age_col=age_col,
        valid_col=valid_col, sb_col=sb_col, ab_col=ab_col)

    cube = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_cols, tile), lambda i: (0, i)),   # column tile
        ],
        out_specs=pl.BlockSpec((N_MEASURES * n_groups, n_sa),
                               lambda i: (0, 0)),             # accumulator
        out_shape=jax.ShapeDtypeStruct((N_MEASURES * n_groups, n_sa),
                                       jnp.float32),
        interpret=interpret,
    )(cols)
    return cube
