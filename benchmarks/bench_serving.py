"""Multi-tenant report serving (PR 7): permission-bitmap plane vs host folds.

The workload is the multi-tenant monitoring loop: a churning catalog
queried continuously by MANY subjects (users scoped to their own files,
group auditors, subtree auditors), every query answered only over what
that subject may see. The store path ANDs the subject's packed
permission bitset into the mesh kernels (one fused AND at serving time);
the host baseline re-folds the catalog columns through
``GrantTable.visible_mask`` for every query. Rows report warm scoped
latency (p50/p99 across the subject mix), the speedup over the
host-filtered folds, and the scoped/unscoped store throughput ratio —
the "tenant scoping is one AND, not a second scan" claim.

``run_serving_assertion`` is the tier-2 CI entry: at bench size on >= 4
(host-platform) devices every scoped answer must be byte-identical to
the grant-filtered host oracle, warm scoped serving must beat the
host-filtered folds by ``min_speedup``, and scoped store throughput must
stay within ``min_scoped_ratio`` of unscoped store throughput.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Catalog, DeviceColumnStore, Entry, FsType,
                        GrantTable, HsmState)
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports

NOW = float(2 ** 20)
FIND_EXPR = "type == file and size > 3900k and last_access > 1000s"

# rows accumulate into the serving trajectory of BENCH_reports.json —
# this suite extends the PR6 report-serving story, not a new table
TRAJECTORY = "reports"


def _catalog(n: int, n_shards: int = 16) -> Catalog:
    rng = np.random.default_rng(0)
    cat = Catalog(n_shards=n_shards)
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        cat.upsert_batch([Entry(
            fid=i + 1, name=f"f{i + 1}", path=f"/fs/d{i % 64}/f{i + 1}",
            type=FsType.FILE if (i % 10) else FsType.DIR,
            size=int(rng.integers(0, 2 ** 12)) * 1024,
            blocks=int(rng.integers(0, 2 ** 10)),
            owner=f"user{i % 8}", group=f"grp{i % 4}",
            hsm_state=HsmState(int(rng.integers(0, 5))),
            atime=NOW - float(rng.integers(0, 10_000)),
            mtime=NOW - float(rng.integers(0, 10_000)),
        ) for i in range(lo, hi)])
    return cat


def _grants() -> GrantTable:
    """A realistic tenant mix: self-owners, a group auditor, a subtree
    auditor and a combined service account."""
    g = GrantTable()
    for u in range(4):
        g.add_subject(f"user{u}")                      # own-files tenants
    g.add_subject("grp-aud", owners=(), groups=("grp1",))
    g.add_subject("tree-aud", owners=(), subtrees=("/fs/d7", "/fs/d21"))
    g.add_subject("svc", owners=("user5",), groups=("grp2",),
                  subtrees=("/fs/d3",))
    return g


SUBJECT_MIX = ["user0", "user1", "user2", "user3", "grp-aud", "tree-aud",
               "svc"]


def _churn(cat: Catalog, n: int, frac: float, round_: int) -> None:
    # same steady-state shape as bench_reports: equal dirty count per
    # shard, rotating fids, so warm scatter executables compile once
    per_shard = max(int(n * frac) // cat.n_shards, 1)
    span = n // cat.n_shards
    fids = [s + cat.n_shards * ((round_ * per_shard + j) % span)
            for s in range(cat.n_shards) for j in range(per_shard)]
    cat.update_fields_batch([f if f else cat.n_shards for f in fids],
                            size=(3 + round_) << 20)


def _kernel_queries(r, subject):
    """The fused-AND family: same kernels scoped and unscoped, so the
    scoped/unscoped throughput ratio is like-for-like."""
    return (r.find(FIND_EXPR, subject=subject),
            r.top_files(k=25, subject=subject),
            r.du("/fs/d7", subject=subject))


def _profile_query(pc, subject):
    # scoped: a full mesh_scoped_cube launch (+ the per-subject burst
    # cache); unscoped: a read of the cached psum-combined cube —
    # different computation classes, so timed and reported separately
    return pc.top_users("volume", 5, NOW, subject=subject)


def _bench_serving(n: int, churn_frac: float, rounds: int,
                   assert_identity: bool = False,
                   assert_speedup: float = 0.0,
                   assert_scoped_ratio: float = 0.0) -> list:
    cat = _catalog(n)
    clock = lambda: NOW                                      # noqa: E731
    grants = _grants()
    store = DeviceColumnStore(cat, mesh=None)                # default mesh
    pc = ProfileCube(cat, clock=clock).attach_device_store(store)
    pc.attach_grants(grants)
    r_store = Reports(cat, clock=clock, profiles=pc) \
        .attach_device_store(store).attach_grants(grants)
    pc_host = ProfileCube(cat, clock=clock)                  # scoped folds
    pc_host.attach_grants(grants)
    r_host = Reports(cat, clock=clock, profiles=pc_host) \
        .attach_grants(grants)

    t0 = time.perf_counter()
    r_store.find(FIND_EXPR, subject="user0")     # cold upload + perm plane
    dt_cold = time.perf_counter() - t0

    # warm every query shape (store scoped + unscoped) so the timed
    # rounds measure steady-state serving, not XLA compilation
    _churn(cat, n, churn_frac, rounds)
    for s in SUBJECT_MIX:
        _kernel_queries(r_store, s)
        _profile_query(pc, s)
    _kernel_queries(r_store, None)
    _profile_query(pc, None)

    lat_scoped, lat_unscoped, lat_host = [], [], []
    lat_prof_s, lat_prof_h = [], []
    dt_refresh = 0.0
    for round_ in range(rounds):
        _churn(cat, n, churn_frac, round_)
        t0 = time.perf_counter()
        store.refresh()                  # shared delta + perm word scatter
        dt_refresh += time.perf_counter() - t0

        for s in SUBJECT_MIX:
            t0 = time.perf_counter()
            got = _kernel_queries(r_store, s)
            lat_scoped.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            got_p = _profile_query(pc, s)
            lat_prof_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            want = _kernel_queries(r_host, s)
            lat_host.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            want_p = _profile_query(pc_host, s)
            lat_prof_h.append(time.perf_counter() - t0)
            if assert_identity:
                assert got == want and got_p == want_p, (
                    f"scoped serving diverged from the grant-filtered "
                    f"host oracle for subject {s!r}")
        t0 = time.perf_counter()
        _kernel_queries(r_store, None)             # unscoped store suite
        lat_unscoped.append(time.perf_counter() - t0)

    n_q = len(_kernel_queries(r_store, None))      # queries per suite call
    scoped = np.asarray(lat_scoped) / n_q          # per query, seconds
    unscoped = np.asarray(lat_unscoped) / n_q
    host = np.asarray(lat_host) / n_q
    prof_s, prof_h = np.asarray(lat_prof_s), np.asarray(lat_prof_h)
    speedup = host.mean() / max(scoped.mean(), 1e-9)
    ratio = unscoped.mean() / max(scoped.mean(), 1e-9)
    qps = 1.0 / max(scoped.mean(), 1e-9)

    rows = [
        ("serving_scoped_cold_upload", 1e6 * dt_cold,
         f"{n}_rows_{len(SUBJECT_MIX)}_subjects_{store.n_devices}_devices"),
        ("serving_refresh_warm", 1e6 * dt_refresh / rounds,
         f"churn_{churn_frac:.0%}_incl_perm_word_scatter"),
        ("serving_scoped_query_p50", 1e6 * float(np.percentile(scoped, 50)),
         f"{qps:.0f}_qps_warm"),
        ("serving_scoped_query_p99", 1e6 * float(np.percentile(scoped, 99)),
         f"subject_mix_{len(SUBJECT_MIX)}"),
        ("serving_scoped_query_warm", 1e6 * float(scoped.mean()),
         f"speedup_{speedup:.2f}x_vs_host_filtered_fold"),
        ("serving_unscoped_query_warm", 1e6 * float(unscoped.mean()),
         f"scoped_over_unscoped_throughput_{ratio:.2f}"),
        ("serving_host_filtered_fold", 1e6 * float(host.mean()),
         f"{n}_rows_visible_mask_per_query"),
        ("serving_scoped_profile", 1e6 * float(prof_s.mean()),
         f"speedup_{prof_h.mean() / max(prof_s.mean(), 1e-9):.2f}x"
         f"_vs_host_scoped_fold"),
    ]

    if assert_identity:
        assert r_store.last_fallback_reason is None, \
            r_store.last_fallback_reason
        assert r_store.host_served == 0 and r_store.store_served > 0
        assert store.perm_materializations >= 1
    if assert_speedup:
        assert speedup >= assert_speedup, (
            f"scoped store serving no longer beats the host-filtered "
            f"folds ({speedup:.2f}x < {assert_speedup}x at n={n}, "
            f"{store.n_devices} devices)")
    if assert_scoped_ratio:
        # the fused AND must stay almost free relative to unscoped serving
        scoped_qps = 1.0 / max(scoped.mean(), 1e-9)
        unscoped_qps = 1.0 / max(unscoped.mean(), 1e-9)
        assert scoped_qps >= assert_scoped_ratio * unscoped_qps, (
            f"scoped throughput {scoped_qps:.0f} qps fell below "
            f"{assert_scoped_ratio:.0%} of unscoped {unscoped_qps:.0f} qps")
    return rows


def run_serving_assertion(n: int = 200_000, min_devices: int = 4,
                          min_speedup: float = 3.0,
                          min_scoped_ratio: float = 0.8) -> list:
    """Tier-2 CI entry: scoped serving is byte-identical to the
    grant-filtered oracle, beats the host folds, and costs ~nothing over
    unscoped store serving."""
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= min_devices, (
        f"need >= {min_devices} devices (run under XLA_FLAGS="
        f"--xla_force_host_platform_device_count=8), have {n_dev}")
    return _bench_serving(n, churn_frac=0.01, rounds=3,
                          assert_identity=True,
                          assert_speedup=min_speedup,
                          assert_scoped_ratio=min_scoped_ratio)


def run(smoke: bool = False) -> list:
    return _bench_serving(20_000 if smoke else 200_000,
                          churn_frac=0.01, rounds=2 if smoke else 3,
                          assert_identity=True)
