"""HSM backend (copytool target) — the 'large, cheap' tier behind Lustre."""
from __future__ import annotations

import threading
from typing import Dict, Optional


class HsmBackend:
    """Stores archived copies keyed by fid (sizes; payload is simulated)."""

    def __init__(self, capacity: int = 1 << 50,
                 archive_latency: float = 0.0) -> None:
        self.capacity = capacity
        self.archive_latency = archive_latency   # per-op simulated latency
        self.used = 0
        self._lock = threading.Lock()
        self._objects: Dict[int, Dict] = {}
        self.puts = 0
        self.gets = 0

    def put(self, fid: int, size: int, archive_id: int = 1) -> None:
        if self.archive_latency:
            import time
            time.sleep(self.archive_latency)
        with self._lock:
            prev = self._objects.get(fid)
            if prev is not None:
                self.used -= prev["size"]
            if self.used + size > self.capacity:
                raise OSError("HSM backend full")
            self._objects[fid] = {"size": size, "archive_id": archive_id}
            self.used += size
            self.puts += 1

    def has(self, fid: int) -> bool:
        with self._lock:
            return fid in self._objects

    def get(self, fid: int) -> int:
        if self.archive_latency:
            import time
            time.sleep(self.archive_latency)
        with self._lock:
            obj = self._objects[fid]
            self.gets += 1
            return obj["size"]

    def remove(self, fid: int) -> None:
        with self._lock:
            obj = self._objects.pop(fid, None)
            if obj is not None:
                self.used -= obj["size"]

    def count(self) -> int:
        with self._lock:
            return len(self._objects)
