"""Batched policy engine: evaluator backends, vectorized attribution,
batch-boundary budget semantics (deterministic across n_threads), and the
batch action interface."""
import threading

import numpy as np
import pytest

from repro.core import (Catalog, Entry, FsType, PolicyDefinition,
                        PolicyEngine, parse_expr)
from repro.core.policy import PolicyError

NOW = 1_000_000.0          # f32-exact; keeps kernel/numpy paths bit-for-bit


def _catalog(n=2000, n_shards=4):
    cat = Catalog(n_shards=n_shards)
    entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/d{i % 7}/f{i}",
                     type=FsType.FILE,
                     size=(i % 50 + 1) * 1000,          # f32-exact sizes
                     blocks=(i % 50 + 1),
                     owner=f"user{i % 5}",
                     atime=NOW - float(i + 1))          # unique LRU order
               for i in range(n)]
    cat.upsert_batch(entries)
    return cat


class Recorder:
    """Thread-safe action that records (fid, params) and can fail."""

    def __init__(self, fail_fids=()):
        self.lock = threading.Lock()
        self.calls = []
        self.fail_fids = set(fail_fids)

    def __call__(self, e, params):
        with self.lock:
            self.calls.append((e.fid, params.get("tag")))
        return e.fid not in self.fail_fids

    def acted(self):
        return sorted(self.calls)


def _engine(cat, action, rules=None, **kw):
    eng = PolicyEngine(cat, clock=lambda: NOW)
    eng.register(PolicyDefinition.from_config(
        name="p", action=action, scope="type == file",
        rules=rules if rules is not None else [("all", "true", {})], **kw))
    return eng


# -- evaluator backends --------------------------------------------------------

def test_policy_scan_evaluator_matches_numpy_bit_for_bit():
    rules = [("big", "size > 30k", {"tag": "big"}),
             ("old", "last_access > 500s", {"tag": "old"})]
    results = {}
    for ev in ("numpy", "policy_scan"):
        cat = _catalog()
        rec = Recorder()
        eng = _engine(cat, rec, rules=rules, n_threads=3, batch_size=128)
        r = eng.run("p", evaluator=ev)
        assert r.evaluator == ev
        results[ev] = (r.matched, r.succeeded, r.failed, r.volume,
                       r.matched_volume, rec.acted())
    assert results["numpy"] == results["policy_scan"]


def test_policy_scan_evaluator_on_empty_catalog():
    """A zero-row catalog matches nothing on every backend (no crash)."""
    cat = Catalog(n_shards=2)
    rec = Recorder()
    eng = _engine(cat, rec, rules=[("big", "size > 1k", {"tag": "big"})])
    for ev in ("numpy", "policy_scan"):
        r = eng.run("p", evaluator=ev)
        assert (r.matched, r.succeeded, r.failed) == (0, 0, 0)
    assert rec.calls == []


def test_policy_scan_falls_back_to_numpy_on_glob():
    cat = _catalog()
    rec = Recorder()
    eng = _engine(cat, rec, rules=[("d3", "path == '/p/d3/*'", {"tag": "d3"})])
    r = eng.run("p", evaluator="policy_scan")
    assert r.evaluator == "numpy"           # glob predicates run on the host
    assert r.matched == r.succeeded > 0


def test_unknown_evaluator_rejected():
    cat = _catalog(50)
    eng = _engine(cat, Recorder())
    with pytest.raises(PolicyError):
        eng.run("p", evaluator="mysql")


# -- vectorized rule attribution -----------------------------------------------

def test_rule_attribution_first_match_wins():
    cat = _catalog()
    rec = Recorder()
    # overlapping conditions: entries matching both must get rule 1's params
    rules = [("big", "size > 25k", {"tag": "big"}),
             ("all", "size > 0", {"tag": "any"})]
    eng = _engine(cat, rec, rules=rules, n_threads=2, batch_size=64)
    r = eng.run("p")
    assert r.succeeded == r.matched == len(cat)
    by_fid = dict(rec.calls)
    cols = cat.arrays()
    for fid, size in zip(cols["fid"].tolist(), cols["size"].tolist()):
        assert by_fid[fid] == ("big" if size > 25_000 else "any")


def test_attribution_agrees_with_scalar_oracle():
    cat = _catalog(500)
    rec = Recorder()
    rules = [("r0", "size > 40k and last_access > 100s", {"tag": "r0"}),
             ("r1", "owner == 'user2'", {"tag": "r1"}),
             ("r2", "size <= 40k", {"tag": "r2"})]
    eng = _engine(cat, rec, rules=rules)
    eng.run("p")
    pol = eng.policies["p"]
    by_fid = dict(rec.calls)
    for e in cat.entries():
        expected = eng._rule_params(pol, e, NOW)
        if expected:
            assert by_fid[e.fid] == expected["tag"]
        else:
            assert e.fid not in by_fid         # matched no rule -> no action


# -- budget semantics ----------------------------------------------------------

def _expected_lru_prefix(cat, target_volume):
    """Oracle: minimal LRU-ordered prefix whose volume meets the target."""
    cols = cat.arrays()
    order = np.argsort(cols["atime"], kind="stable")
    fids = cols["fid"][order]
    sizes = cols["size"][order]
    csum = np.cumsum(sizes)
    k = int(np.searchsorted(csum, target_volume)) + 1
    k = min(k, len(fids))
    return fids[:k].tolist(), int(csum[k - 1])


@pytest.mark.parametrize("n_threads", [1, 3, 8])
def test_target_volume_never_overshoots_and_is_deterministic(n_threads):
    target = 137_000
    cat = _catalog()
    exp_fids, exp_volume = _expected_lru_prefix(cat, target)
    rec = Recorder()
    eng = _engine(cat, rec, n_threads=n_threads, batch_size=100)
    r = eng.run("p", target_volume=target)
    acted = [f for f, _ in rec.calls]
    assert sorted(acted) == sorted(exp_fids)
    assert r.succeeded == len(exp_fids)
    assert r.volume == exp_volume
    assert r.volume >= target                      # target reached...
    max_size = max(e.size for e in cat.entries())
    assert r.volume < target + max_size            # ...but never overshot
    assert r.rounds == 1


@pytest.mark.parametrize("n_threads", [1, 4])
def test_max_actions_is_exact_and_deterministic(n_threads):
    cat = _catalog()
    rec = Recorder()
    eng = _engine(cat, rec, n_threads=n_threads, batch_size=32,
                  max_actions_per_run=77)
    r = eng.run("p")
    assert r.succeeded == 77
    # deterministic: the 77 oldest (LRU) entries, not whichever thread won
    exp = sorted(_expected_lru_prefix(cat, 10**18)[0][:77])
    assert sorted(f for f, _ in rec.calls) == exp


def test_failures_trigger_replanning_rounds_until_target_met():
    cat = _catalog()
    fail = {fid for fid in range(1, 2001) if fid % 2 == 0}
    rec = Recorder(fail_fids=fail)
    eng = _engine(cat, rec, n_threads=2, batch_size=100)
    target = 100_000
    r = eng.run("p", target_volume=target)
    assert r.volume >= target                 # failed sizes don't count...
    assert r.failed > 0
    assert r.rounds > 1                       # ...so the engine re-planned
    attempted = [f for f, _ in rec.calls]
    assert len(attempted) == len(set(attempted))   # each entry tried once


def test_watermark_trigger_budget_stop():
    from repro.core import UsageWatermarkTrigger
    cat = _catalog()
    freed = [0]
    lock = threading.Lock()

    def act(e, params):
        with lock:
            freed[0] += e.size
        return True

    capacity = 1_000_000
    used0 = 900_000
    eng = _engine(cat, act, n_threads=4, batch_size=64)
    eng.add_watermark_trigger("p", UsageWatermarkTrigger(
        usage_fn=lambda: [("ost0", used0 - freed[0], capacity)],
        high_pct=85.0, low_pct=60.0,
        restrict_fn=lambda key: parse_expr("true")))
    reports = eng.check_triggers()
    assert len(reports) == 1
    target = used0 - int(capacity * 0.60)
    assert reports[0].trigger == "watermark:ost0"
    assert reports[0].volume >= target
    max_size = max(e.size for e in cat.entries())
    assert reports[0].volume < target + max_size
    assert used0 - freed[0] <= capacity * 0.60 + max_size
    assert not eng.check_triggers()           # back under the high watermark


# -- execution paths -----------------------------------------------------------

def test_batch_action_interface_used_and_equivalent():
    """Columnar default: action_batch consumes ColumnBatch, no Entries."""
    from repro.core import ColumnBatch
    cat = _catalog()
    batch_sizes = []
    scalar_calls = []
    payload_types = []
    lock = threading.Lock()

    def action(e, params):
        with lock:
            scalar_calls.append(e.fid)
        return True

    def action_batch(batch, params):
        with lock:
            batch_sizes.append(len(batch))
            payload_types.append(type(batch))
        return (batch.fids % 10 != 0).tolist()

    action.action_batch = action_batch
    eng = _engine(cat, action, n_threads=2, batch_size=128)
    r = eng.run("p")
    assert not scalar_calls                    # batch interface preferred
    assert all(t is ColumnBatch for t in payload_types)
    assert sum(batch_sizes) == r.matched
    assert max(batch_sizes) <= 128
    assert r.failed == sum(1 for e in cat.entries() if e.fid % 10 == 0)
    assert r.succeeded == r.matched - r.failed


def test_needs_entries_declaration_materializes():
    """A plugin declaring needs_entries gets List[Entry], even columnar."""
    cat = _catalog()
    payloads = []
    lock = threading.Lock()

    def action(e, params):
        return True

    def action_batch(entries, params):
        with lock:
            payloads.append(entries)
        return [e.fid % 10 != 0 for e in entries]

    action.action_batch = action_batch
    action.needs_entries = True
    eng = _engine(cat, action, n_threads=1, batch_size=128)
    r = eng.run("p", execution="columnar")
    assert payloads and all(isinstance(p, list) for p in payloads)
    assert all(isinstance(e, Entry) for p in payloads for e in p)
    assert r.failed == sum(1 for e in cat.entries() if e.fid % 10 == 0)


def test_batched_mode_shim_matches_columnar():
    """Legacy batched mode feeds the same ColumnBatch-consuming plugin via
    the from_entries shim: identical outcomes, Entry cost paid."""
    results = {}
    for execution in ("columnar", "batched"):
        cat = _catalog(800)
        acted = []
        lock = threading.Lock()

        def action(e, params):
            return True

        def action_batch(batch, params):
            with lock:
                acted.extend(batch.fids.tolist())
            return [True] * len(batch)

        action.action_batch = action_batch
        eng = _engine(cat, action, n_threads=1, batch_size=64)
        r = eng.run("p", execution=execution)
        assert r.execution == execution
        results[execution] = (r.matched, r.succeeded, r.volume, sorted(acted))
    assert results["columnar"] == results["batched"]


def test_scalar_execution_path_agrees_with_batched():
    results = {}
    for execution in ("columnar", "batched", "scalar"):
        cat = _catalog(800)
        rec = Recorder()
        eng = _engine(cat, rec, n_threads=1, batch_size=64)
        r = eng.run("p", execution=execution)
        results[execution] = (r.matched, r.succeeded, r.volume, rec.acted())
    assert results["batched"] == results["scalar"] == results["columnar"]


def test_dry_run_counts_without_calling_actions():
    cat = _catalog()
    rec = Recorder()
    eng = _engine(cat, rec, dry_run=True)
    r = eng.run("p")
    assert rec.calls == []
    assert r.succeeded == r.matched == len(cat)
    assert r.volume == r.matched_volume == sum(e.size for e in cat.entries())


def test_fallback_reason_records_evaluator_downgrades():
    """RunReport carries the evaluator actually used AND why a requested
    kernel/mesh backend degraded, so benchmarks/CI can assert the fast
    path really ran instead of silently timing numpy."""
    cat = _catalog(300)
    rec = Recorder()
    # numeric-only criteria: the kernel path runs, nothing to report
    eng = _engine(cat, rec, rules=[("big", "size > 30k", {})])
    r = eng.run("p", evaluator="policy_scan")
    assert r.evaluator == "policy_scan" and r.fallback_reason == ""
    # glob predicate: silently-swallowed PolicyError is now on the report
    eng2 = _engine(cat, rec, rules=[("glob", "path == '/p/d1/*'", {})])
    r2 = eng2.run("p", evaluator="policy_scan")
    assert r2.evaluator == "numpy"
    assert "policy_scan->numpy" in r2.fallback_reason
    assert "glob" in r2.fallback_reason
    # mesh without a store downgrades through the whole chain
    r3 = eng2.run("p", evaluator="policy_scan_mesh")
    assert r3.evaluator == "numpy"
    assert "policy_scan_mesh->policy_scan" in r3.fallback_reason
    assert "no device store attached" in r3.fallback_reason
    # numpy asked for explicitly: no fallback to report
    r4 = eng.run("p", evaluator="numpy")
    assert r4.evaluator == "numpy" and r4.fallback_reason == ""
