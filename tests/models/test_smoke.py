"""Per-arch smoke tests: reduced config, one forward + train step on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.optim import AdamW
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _extras(cfg):
    if cfg.encoder is not None:
        return {"frames": jnp.ones((B, cfg.encoder.n_frames, cfg.d_model),
                                   jnp.bfloat16) * 0.01}
    if cfg.n_img_tokens:
        return {"img": jnp.ones((B, cfg.n_img_tokens, cfg.d_model),
                                jnp.bfloat16) * 0.01}
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg, kv_chunk=16)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits, aux, _ = m.forward(params, toks, _extras(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss_or_finite(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg, kv_chunk=16)
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    state = init_train_state(m, opt, KEY)
    step = jax.jit(make_train_step(m, opt))
    toks = jax.random.randint(KEY, (1, B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    ex = _extras(cfg)
    if ex is not None:
        batch["extras"] = {k: v[None] for k, v in ex.items()}
    losses = []
    for i in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]      # same batch -> must overfit


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    spec = {
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6_1p6b": (24, 2048, 32, 32, 7168, 65536),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "codeqwen1p5_7b": (32, 4096, 32, 32, 13440, 92416),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "llama3p2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
    }
    for arch, (L, D, H, K, F, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
                cfg.vocab) == (L, D, H, K, F, V), arch


def test_moe_param_counts():
    cfg = get_config("mixtral_8x22b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 120e9 < total < 160e9          # ~141B
    assert 35e9 < active < 50e9           # ~39B active (top-2 of 8)
    cfg4 = get_config("llama4_maverick_400b_a17b")
    assert 350e9 < cfg4.param_count() < 450e9
    assert 12e9 < cfg4.active_param_count() < 25e9
