"""Gradient compression for the data-parallel reduce (distributed-opt trick).

Error-feedback int8 quantization: each DP shard quantizes its local gradient
contribution to int8 with a per-tensor scale, all-reduces the int8 payload
widened to int32 (4x fewer wire *payload* bits than f32 — the sum must not
overflow, and on TPU the ICI transfer of the int8->int32 widened tensor is
what we model; see EXPERIMENTS.md SPerf), dequantizes, and keeps the
quantization residual locally to add into the next step (error feedback
preserves convergence; Karimireddy et al. 2019).

Used via ``shard_map`` over the dp axis so the reduce is explicit (GSPMD's
implicit gradient all-reduce bypasses any compression opportunity).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

PyTree = Any


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns fn(grads_local, err_state) -> (grads_mean, new_err_state).

    Must be called inside ``shard_map`` with ``axis`` unmapped in outputs.
    """
    n = mesh.shape[axis]

    def reduce_one(g: jax.Array, err: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
        gf = g.astype(jnp.float32) + err
        # SHARED scale across shards (pmax): int8 payloads quantized against
        # different scales cannot be summed; the pmax is a scalar collective
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_err = gf - q.astype(jnp.float32) * scale
        # widen before the sum so int8 accumulation cannot overflow
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        g_mean = q_sum.astype(jnp.float32) * scale / n
        return g_mean.astype(g.dtype), new_err

    def reduce_tree(grads: PyTree, err_state: PyTree
                    ) -> Tuple[PyTree, PyTree]:
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err_state)
        out = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return reduce_tree


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
