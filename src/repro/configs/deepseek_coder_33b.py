"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama arch. [arXiv:2401.14196; hf]
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

_PATTERN = (LayerSpec(mix=ATTN_FULL),)

CONFIG = ModelConfig(
    name="deepseek_coder_33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv=8, head_dim=128,
    d_ff=19200, vocab=32256,
    pattern=_PATTERN, rope_theta=1e5,
)

SMOKE = ModelConfig(
    name="deepseek_smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN,
)
