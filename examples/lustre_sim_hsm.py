"""The paper's headline scenario end-to-end on the simulated Lustre:

changelog-driven mirror -> O(1) accounting -> HSM archival -> OST
watermark purge -> transparent restore -> undelete.

    PYTHONPATH=src python examples/lustre_sim_hsm.py
"""
import time

from repro.core import (AlertManager, AlertRule, Catalog, EventPipeline,
                        HsmCoordinator, PipelineConfig, PolicyEngine,
                        Reports, Scanner, StatsAggregator)
from repro.fs import HsmBackend, LustreSim


def main() -> None:
    fs = LustreSim(n_osts=4, ost_capacity=200_000, n_mdts=2,
                   hsm=HsmBackend())
    home = fs.mkdir(fs.root_fid(), "home")
    ann = fs.mkdir(home, "ann", owner="ann")
    bob = fs.mkdir(home, "bob", owner="bob")

    catalog = Catalog(n_shards=4)
    stats = StatsAggregator(catalog.strings)
    catalog.add_delta_hook(stats.on_delta)
    alerts = AlertManager()
    alerts.add_rule(AlertRule("huge_file", "size > 64k"))
    catalog.add_entry_hook(alerts.on_entry)

    Scanner(fs, catalog, n_threads=2).scan()
    pipes = [EventPipeline(fs, catalog, fs.changelog.stream(m),
                           PipelineConfig()) for m in range(2)]

    print("== users write data; the DB follows via MDT changelogs ==")
    for i in range(60):
        owner, d = ("ann", ann) if i % 2 else ("bob", bob)
        f = fs.create(d, f"run{i}.out", owner=owner, uid=owner,
                      jobid=f"job{i % 4}")
        fs.write(f, 5000 + 1000 * (i % 30), uid=owner)
    for p in pipes:
        p.process_once(10_000)
    rep = Reports(catalog, stats)
    print(rep.format_user_report("ann"))
    print("alerts fired:", len(alerts.fired))
    for o in fs.osts:
        print(f"  OST{o.index}: {o.usage_pct:.1f}% used")

    print("\n== archive everything old enough, then watermark purge ==")
    engine = PolicyEngine(catalog)
    coord = HsmCoordinator(fs, catalog, engine, archive_age="0s",
                           high_wm=40.0, low_wm=15.0)
    r = coord.archive_pass()
    print(f"archived {r.succeeded} files "
          f"({r.volume} bytes) to the HSM backend")
    for rr in coord.space_check():
        print(f"purge[{rr.trigger}]: released {rr.succeeded} files, "
              f"freed {rr.volume} bytes")
    for o in fs.osts:
        print(f"  OST{o.index}: {o.usage_pct:.1f}% used")
    for p in pipes:
        p.process_once(10_000)
    print("HSM states:", {k: v["count"]
                          for k, v in stats.report_hsm().items()})

    print("\n== transparent restore on read ==")
    released = [e for e in catalog.entries() if e.hsm_state == 4]
    victim = released[0]
    size = fs.read(victim.fid, uid="ann")
    print(f"read {victim.path}: {size} bytes "
          f"(now {fs.stat(victim.fid).hsm_state.name})")

    print("\n== undelete ==")
    target = [e for e in catalog.entries() if e.hsm_state in (3, 4)
              and e.fid != victim.fid][0]
    fs.unlink(target.fid)
    print(f"deleted {target.path}; undeleting from the archive...")
    new_fid = coord.undelete(target.fid, ann, "recovered.out")
    print(f"recovered as fid {new_fid}: {fs.stat(new_fid).size} bytes")


if __name__ == "__main__":
    main()
