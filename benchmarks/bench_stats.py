"""Paper SII-B3 + SIII-C: O(1) pre-aggregated reports vs full aggregation.

The claim: `rbh-report -u foo` is O(1) in catalog size because aggregates
are maintained at ingest. We time the query at growing catalog sizes for
both the pre-aggregated path and a from-scratch recomputation.

Profile-cube cases (this repo's third data plane): the scalar
``StatsAggregator`` fold (one python dict update per delta) vs the
``ProfileCube`` vectorized per-shard build, and incremental signed-delta
maintenance at 1% churn vs a full cube recompute. CI gates on
``profile_cube_build`` beating ``stats_scalar_fold`` and
``profile_cube_incremental`` beating ``profile_cube_recompute``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Catalog, Entry, FsType, ProfileCube, Reports,
                        StatsAggregator)


def _fill(cat, stats, n):
    rng = np.random.default_rng(0)
    owners = [f"user{i}" for i in range(20)]
    entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                     type=FsType.FILE, size=int(rng.integers(0, 1 << 30)),
                     blocks=100, owner=owners[int(rng.integers(0, 20))])
               for i in range(n)]
    cat.upsert_batch(entries)


def _cube_catalog(n: int, now: float) -> Catalog:
    """n entries with spread owners/groups/ages, chunked build."""
    rng = np.random.default_rng(1)
    cat = Catalog(n_shards=4)
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                         type=FsType.FILE,
                         size=int(rng.integers(0, 1 << 30)), blocks=100,
                         owner=f"user{int(rng.integers(0, 16))}",
                         group=f"grp{int(rng.integers(0, 4))}",
                         atime=now - float(rng.integers(0, 400 * 86400)))
                   for i in range(lo, hi)]
        cat.upsert_batch(entries)
    return cat


def _bench_profile_cube(n: int) -> list:
    """Cube build vs scalar fold, incremental vs recompute at 1% churn."""
    now = time.time()
    cat = _cube_catalog(n, now)
    clock = lambda: now  # noqa: E731

    # scalar StatsAggregator fold: one python dict fold per delta (the
    # pre-cube maintenance cost for the same catalog)
    deltas = []
    for shard in cat.shards:
        with shard.lock:
            for row in shard._rows.values():
                deltas.append(shard._row_delta(row))
    scalar = StatsAggregator(cat.strings)
    t0 = time.perf_counter()
    for d in deltas:
        scalar._apply(None, d)
    scalar_dt = time.perf_counter() - t0

    # profile cube: vectorized per-shard build (snapshot + groupby)
    cube = ProfileCube(cat, clock=clock)
    t0 = time.perf_counter()
    cube.rebuild(now=now)
    build_dt = time.perf_counter() - t0
    assert cube.totals()[0] == scalar.total.count

    # 1% churn: size/atime updates flow through the delta hook
    cat.add_delta_hook(cube.on_delta)
    rng = np.random.default_rng(2)
    churn = (rng.choice(n, max(1, n // 100), replace=False) + 1).tolist()
    for fid in churn:
        cat.update_fields(fid, size=123456, atime=now - 50.0)

    t0 = time.perf_counter()
    cube.cube(now)                       # flush signed deltas + rollovers
    inc_dt = time.perf_counter() - t0

    fresh = ProfileCube(cat, clock=clock)
    t0 = time.perf_counter()
    fresh.rebuild(now=now)               # full cube recompute
    recompute_dt = time.perf_counter() - t0
    assert fresh.totals() == cube.totals()

    return [
        (f"stats_scalar_fold_n{n}", scalar_dt * 1e6,
         f"{n / scalar_dt:.0f}_deltas_per_s"),
        (f"profile_cube_build_n{n}", build_dt * 1e6,
         f"vs_scalar_fold_{scalar_dt / build_dt:.1f}x"),
        (f"profile_cube_recompute_n{n}", recompute_dt * 1e6,
         f"churn_{len(churn)}_rows"),
        (f"profile_cube_incremental_n{n}", inc_dt * 1e6,
         f"vs_recompute_{recompute_dt / inc_dt:.1f}x"),
    ]


def run(smoke: bool = False) -> list:
    rows = []
    for n in ((10_000, 40_000) if smoke else (10_000, 40_000, 160_000)):
        cat = Catalog(n_shards=4)
        stats = StatsAggregator(cat.strings)
        cat.add_delta_hook(stats.on_delta)
        t0 = time.perf_counter()
        _fill(cat, stats, n)
        ingest_dt = time.perf_counter() - t0
        rep = Reports(cat, stats)
        # O(1) pre-aggregated query
        t0 = time.perf_counter()
        for _ in range(200):
            rep.report_user("user7")
        o1 = (time.perf_counter() - t0) / 200
        # from-scratch aggregation over the columns (what MySQL would do)
        cols = cat.arrays()
        code = cat.strings.code_of("user7")
        t0 = time.perf_counter()
        for _ in range(5):
            m = cols["owner"] == code
            (m.sum(), cols["size"][m].sum(), cols["blocks"][m].sum())
        full = (time.perf_counter() - t0) / 5
        rows.append((f"report_preagg_n{n}", o1 * 1e6,
                     f"flat_vs_scan_{full/o1:.0f}x"))
        rows.append((f"report_fullscan_n{n}", full * 1e6,
                     f"ingest_{n/ingest_dt:.0f}_entries_per_s"))
    rows.extend(_bench_profile_cube(120_000 if smoke else 1_000_000))
    return rows
