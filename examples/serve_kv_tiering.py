"""Serve batched requests while the policy engine tiers KV pages
HBM <-> host underneath (the paper's OST-watermark purge, adapted).

    PYTHONPATH=src python examples/serve_kv_tiering.py
"""
from repro.serve.engine import PagedLMConfig, Request, ServingEngine


def main() -> None:
    cfg = PagedLMConfig(n_layers=2, n_pages=20, page_size=8,
                        high_wm=70.0, low_wm=40.0)
    engine = ServingEngine(cfg, seed=0)
    requests = [
        Request(req_id=i, prompt=[(13 * i + j) % cfg.vocab
                                  for j in range(10)], max_new=12)
        for i in range(5)
    ]
    print(f"serving {len(requests)} requests; hot pool = "
          f"{cfg.n_pages} pages x {cfg.page_size} tokens per layer")
    done = engine.run(requests, policy_interval=2)
    for r in done:
        print(f"  req{r.req_id}: generated {r.generated}")
    for li, rep in enumerate(engine.tier_report()):
        print(f"layer {li} tier report: {rep}")
    cache = engine.caches[0]
    print("\nper-sequence O(1) residency stats during run were available "
          "via cache.residency_report(seq_id) — pages now freed:",
          cache.tier_report())


if __name__ == "__main__":
    main()
