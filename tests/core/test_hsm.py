"""HSM archive/release state machine + OST watermark purge (C7/C8)."""
import pytest

from repro.core import (Catalog, HsmCoordinator, HsmState, PolicyEngine,
                        Scanner)
from repro.fs import HsmBackend, LustreSim


def _setup(n_files=20, fsize=1000, ost_capacity=8000, n_osts=2,
           clock=None):
    kw = dict(clock=clock) if clock else {}
    fs = LustreSim(n_osts=n_osts, ost_capacity=ost_capacity,
                   hsm=HsmBackend(), **kw)
    d = fs.mkdir(fs.root_fid(), "data")
    fids = []
    for i in range(n_files):
        f = fs.create(d, f"f{i}", owner="u")
        fs.write(f, fsize)
        fids.append(f)
    cat = Catalog()
    Scanner(fs, cat).scan()
    eng = PolicyEngine(cat, clock=clock) if clock else PolicyEngine(cat)
    return fs, d, fids, cat, eng


def test_archive_then_release_frees_ost_space(fake_clock):
    fs, d, fids, cat, eng = _setup(clock=fake_clock)
    coord = HsmCoordinator(fs, cat, eng, high_wm=50.0, low_wm=20.0)
    rep = coord.archive_pass()
    assert rep.succeeded == 20 and rep.failed == 0
    assert fs.hsm.count() == 20
    used_before = sum(o.used for o in fs.osts)
    fake_clock.advance(100)
    reports = coord.space_check()        # OSTs above 50% -> purge to 20%
    assert reports, "watermark should have fired"
    used_after = sum(o.used for o in fs.osts)
    assert used_after < used_before
    for o in fs.osts:
        assert o.usage_pct <= 50.0
    # released entries are stubs: size kept, blocks 0
    released = [f for f in fids
                if cat.get(f) and cat.get(f).hsm_state == HsmState.RELEASED]
    assert released
    e = cat.get(released[0])
    assert e.size == 1000 and e.blocks == 0


def test_read_restores_released_file(fake_clock):
    fs, d, fids, cat, eng = _setup(clock=fake_clock)
    coord = HsmCoordinator(fs, cat, eng)
    coord.archive_pass()
    fs.hsm_release(fids[0])
    assert fs.stat(fids[0]).hsm_state == HsmState.RELEASED
    size = fs.read(fids[0])              # transparent restore
    assert size == 1000
    assert fs.stat(fids[0]).hsm_state == HsmState.ARCHIVED
    assert fs.stat(fids[0]).blocks == 1000


def test_dirty_after_write_requires_rearchive(fake_clock):
    fs, d, fids, cat, eng = _setup(clock=fake_clock)
    coord = HsmCoordinator(fs, cat, eng)
    coord.archive_pass()
    fs.write(fids[1], 50)
    assert fs.stat(fids[1]).hsm_state == HsmState.DIRTY
    with pytest.raises(RuntimeError):
        fs.hsm_release(fids[1])          # cannot release a dirty file


def test_undelete(fake_clock):
    fs, d, fids, cat, eng = _setup(clock=fake_clock)
    coord = HsmCoordinator(fs, cat, eng)
    coord.archive_pass()
    victim = fids[2]
    fs.unlink(victim)
    assert fs.stat(victim) is None
    new_fid = coord.undelete(victim, d, "f2_restored")
    assert new_fid is not None
    assert fs.stat(new_fid).size == 1000


def test_disaster_recovery_rebuild(fake_clock):
    fs, d, fids, cat, eng = _setup(clock=fake_clock)
    # catalog lost: rebuild by scan
    cat2 = Catalog()
    eng2 = PolicyEngine(cat2, clock=fake_clock)
    coord = HsmCoordinator(fs, cat2, eng2)
    n = coord.rebuild_catalog()
    assert n == fs.count()
    assert len(cat2) == fs.count()
