"""Mesh-resident analytics plane (PR 6): store-backed reports vs host folds.

The workload is the realistic monitoring loop: a churning catalog queried
continuously (`rbh-find` / top-N / `rbh-du` / `rbh-report` profiles).
The host folds re-concat the catalog columns (and re-gather the lazy
path lists) every time the version ticks; the device store scatters only
the dirty rows into resident blocks and answers from them. Rows compare
warm store-backed queries against the host oracle at identical state,
asserting byte-identical answers along the way.

``run_mesh_assertion`` is the tier-2 CI entry: at bench size on >= 4
(host-platform) devices the store-backed path must have served every
query (no ``fallback_reason``) and beat the host fold.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Catalog, DeviceColumnStore, Entry, FsType, HsmState
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports

NOW = float(2 ** 20)
# selective, like a real candidate listing — the cost under test is the
# full-column evaluation, not building a python list of half the paths
FIND_EXPR = "type == file and size > 3900k and last_access > 1000s"


def _catalog(n: int, n_shards: int = 16) -> Catalog:
    rng = np.random.default_rng(0)
    cat = Catalog(n_shards=n_shards)
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        cat.upsert_batch([Entry(
            fid=i + 1, name=f"f{i + 1}", path=f"/fs/d{i % 64}/f{i + 1}",
            type=FsType.FILE if (i % 10) else FsType.DIR,
            size=int(rng.integers(0, 2 ** 12)) * 1024,
            blocks=int(rng.integers(0, 2 ** 10)),
            owner=f"user{i % 8}", group=f"grp{i % 4}",
            hsm_state=HsmState(int(rng.integers(0, 5))),
            atime=NOW - float(rng.integers(0, 10_000)),
            mtime=NOW - float(rng.integers(0, 10_000)),
        ) for i in range(lo, hi)])
    return cat


def _churn(cat: Catalog, n: int, frac: float, round_: int) -> None:
    # equal dirty count per shard, rotating through distinct fids each
    # round: every device's group dirties with the SAME padded scatter
    # bucket every time, so the executables compile once (in the warmup
    # round) and stay warm — exactly the steady state a changelog-fed
    # deployment runs in
    per_shard = max(int(n * frac) // cat.n_shards, 1)
    span = n // cat.n_shards
    fids = [s + cat.n_shards * ((round_ * per_shard + j) % span)
            for s in range(cat.n_shards) for j in range(per_shard)]
    cat.update_fields_batch([f if f else cat.n_shards for f in fids],
                            size=(3 + round_) << 20)


def _bench_reports_mesh(n: int, churn_frac: float, rounds: int,
                        assert_no_fallback: bool = False,
                        assert_speedup: float = 0.0) -> list:
    cat = _catalog(n)
    clock = lambda: NOW                                      # noqa: E731
    store = DeviceColumnStore(cat, mesh=None)                # default mesh
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    r_host = Reports(cat, clock=clock)
    pc_store = ProfileCube(cat, clock=clock).attach_device_store(store)

    t0 = time.perf_counter()
    r_store.find(FIND_EXPR)                                  # cold upload
    pc_store.totals()                                        # cold cube
    dt_cold = time.perf_counter() - t0

    # warm the jit caches: every query shape compiles once here, so the
    # timed rounds measure steady-state serving, not XLA compilation
    _churn(cat, n, churn_frac, rounds)
    r_store.find(FIND_EXPR)
    r_store.top_files(k=25)
    r_store.du("/fs/d7")
    pc_store.top_users("volume", 5, NOW)

    dt_store = {"refresh": 0.0, "find": 0.0, "top": 0.0, "du": 0.0,
                "profile": 0.0}
    dt_host = dict(dt_store)
    for round_ in range(rounds):
        _churn(cat, n, churn_frac, round_)

        # the delta scatter is shared by every query this round — timed
        # once, not inside whichever query happens to run first
        t0 = time.perf_counter()
        store.refresh()
        dt_store["refresh"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        f_s = r_store.find(FIND_EXPR)
        dt_store["find"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        t_s = r_store.top_files(k=25)
        dt_store["top"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        d_s = r_store.du("/fs/d7")
        dt_store["du"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        p_s = pc_store.top_users("volume", 5, NOW)
        dt_store["profile"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        f_h = r_host.find(FIND_EXPR)
        dt_host["find"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        t_h = r_host.top_files(k=25)
        dt_host["top"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        d_h = r_host.du("/fs/d7")
        dt_host["du"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        pc_host = ProfileCube(cat, clock=clock)              # the host fold
        pc_host.rebuild(now=NOW)
        p_h = pc_host.top_users("volume", 5, NOW)
        dt_host["profile"] += time.perf_counter() - t0

        assert f_s == f_h and t_s == t_h and d_s == d_h and p_s == p_h, \
            "store-backed reports diverged from the host oracle"

    rows = [("reports_store_cold_upload", 1e6 * dt_cold,
             f"{n}_rows_{store.n_devices}_devices"),
            ("reports_store_warm_refresh", 1e6 * dt_store["refresh"] / rounds,
             f"churn_{churn_frac:.0%}_shared_by_all_queries")]
    total_s, total_h = dt_store["refresh"] / rounds, 0.0
    for key in ("find", "top", "du", "profile"):
        s, h = dt_store[key] / rounds, dt_host[key] / rounds
        total_s, total_h = total_s + s, total_h + h
        rows.append((f"reports_{key}_store_warm", 1e6 * s,
                     f"speedup_{h / max(s, 1e-9):.2f}x_vs_host"))
        rows.append((f"reports_{key}_host_fold", 1e6 * h,
                     f"{n}_rows_churn_{churn_frac:.0%}"))
    speedup = total_h / max(total_s, 1e-9)
    rows.append(("reports_suite_store_warm", 1e6 * total_s,
                 f"suite_speedup_{speedup:.2f}x_incl_refresh"))

    if assert_no_fallback:
        assert r_store.last_fallback_reason is None, \
            r_store.last_fallback_reason
        assert r_store.host_served == 0 and r_store.store_served > 0
        assert store.cube_rebuilds == 1, (
            f"warm rounds forced {store.cube_rebuilds} cube rebuilds — "
            "the scatter-add maintenance path regressed")
    if assert_speedup:
        assert speedup >= assert_speedup, (
            f"store-backed report suite no longer beats the host folds "
            f"({speedup:.2f}x < {assert_speedup}x at n={n}, "
            f"{store.n_devices} devices)")
    return rows


def run_mesh_assertion(n: int = 300_000, min_devices: int = 4,
                       min_speedup: float = 3.0) -> list:
    """Tier-2 CI entry: store-backed reports served everything (no
    fallback) and beat the host folds at bench size on a real mesh."""
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= min_devices, (
        f"need >= {min_devices} devices (run under XLA_FLAGS="
        f"--xla_force_host_platform_device_count=8), have {n_dev}")
    return _bench_reports_mesh(n, churn_frac=0.01, rounds=3,
                               assert_no_fallback=True,
                               assert_speedup=min_speedup)


def run(smoke: bool = False) -> list:
    return _bench_reports_mesh(20_000 if smoke else 200_000,
                               churn_frac=0.01, rounds=2 if smoke else 3,
                               assert_no_fallback=True)
