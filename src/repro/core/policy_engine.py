"""Generic policy engine (C5, C7, C10) — robinhood v3 plugin architecture.

A *policy* is: a **scope** (criteria restricting which entries it may ever
touch), ordered **rules** (criteria -> parameters), an **action** (plugin
callable), **triggers** (periodic / usage-watermark / manual), and run
options (sort order, rate limits, target volume/count).

This is the paper's v3 "generic policies": archive/purge/rmdir are just
shipped plugin configurations; users register custom actions the same way
(see ``plugins.py``). Watermark triggers reproduce the per-OST purge (C7):
when an OST exceeds ``high_wm``, the engine runs the policy restricted to
entries striped on that OST until usage is projected below ``low_wm``.

Execution is **columnar, batched and shard-parallel** (paper SII-B1: policy
runs over billions of entries must never degenerate into per-entry scans).
The hot path never constructs a per-entry Python object and never launches
more than one kernel per shard batch:

* **matching** goes through a pluggable evaluator backend — ``"numpy"``
  (vectorized column masks), ``"policy_scan"`` (the Pallas TPU kernel,
  falling back to its jitted oracle off-TPU) or ``"policy_scan_mesh"``
  (the same program batch evaluated data-parallel over a device-resident
  :class:`~repro.core.device_store.DeviceColumnStore` — see
  :meth:`PolicyEngine.attach_device_store`; no per-run host concat or
  host→device re-upload, stale shard groups refresh by delta scatter).
  The kernel backends evaluate the policy's whole (R, P) rule-program
  batch in a SINGLE launch (per device) that writes the (R, N) mask tile
  with first-match-wins rule **attribution** and per-rule size/blocks
  reductions fused on-device (the per-rule-launch path survives inside
  ``match_programs`` as a fallback and differential oracle). Evaluator
  downgrades (mesh without a store, glob predicates) are recorded on
  ``RunReport.fallback_reason`` so callers can assert the requested
  backend really ran;
* **budgets** (target volume / max actions) are planned on batch
  boundaries over the match-time column snapshot — no entry objects: the
  engine takes the minimal prefix of the sorted candidate list whose
  projected volume meets the remaining target, executes it, and only
  re-plans if failures left the target unmet. The actioned set is a pure
  function of the catalog snapshot — deterministic across ``n_threads``,
  with no overshoot races;
* **execution** draws work in fid chunks from a deque; under the default
  ``execution="columnar"`` each chunk is fetched as a
  :class:`~repro.core.catalog.ColumnBatch` (one numeric column gather per
  shard group, lazy string decode, zero ``Entry.__init__``) and applied
  through the action's batch interface
  (``action.action_batch(batch, params) -> list[bool]``). ``Entry``
  objects are materialized ONLY for actions that declare
  ``needs_entries = True`` (their ``action_batch`` then receives
  ``List[Entry]``) and for scalar-only actions.

Two slower paths are kept so ``benchmarks/bench_policy.py`` can report the
speedups honestly: ``execution="batched"`` (the pre-columnar path — every
chunk materializes Entries via :meth:`Catalog.get_batch`, then batch
actions run off a ``ColumnBatch.from_entries`` shim so plugin code is
byte-identical across modes) and ``execution="scalar"`` (per-entry
catalog.get + Python rule re-evaluation).

Incremental match (paper SII-C: changelogs replace re-scans)
------------------------------------------------------------

Policy runs do not have to re-scan the catalog: once the engine is wired to
a delta source — :meth:`PolicyEngine.subscribe_pipeline` (the changelog
pipeline's post-commit fan-out), :meth:`PolicyEngine.subscribe_stream` /
:meth:`subscribe_hub` (a named changelog subscriber that trails the
pipeline's ack watermark), or explicit :meth:`mark_dirty` calls — it keeps
per-policy **incremental match state**:

* a **dirty-fid set** of entries touched since the last run;
* a cached **match table** (fid -> size, sort key, first-matching rule) for
  every entry currently satisfying ``scope AND any(rules)``;
* a **flip schedule** for age predicates (``last_access > 30d`` flips at
  ``atime + 30d`` with no delta arriving): per entry, the earliest future
  instant its match status can change through time alone.

An incremental run re-evaluates only ``dirty ∪ time-due`` rows — gathered
by fid via :meth:`Catalog.gather_rows`, no full-column snapshot — merges
the verdicts into the cached table, and plans/sorts/budgets from the table
exactly like a full run. Watermark ``extra_criteria`` are applied freshly
on top of the cached set each run (they can only restrict it). After a
non-dry run, actioned fids are marked dirty again so plugin-made catalog
mutations are re-observed.

Runs fall back to a **full columnar scan** when: (1) no state exists yet —
the first run (or any run after :meth:`invalidate`, e.g. on a changelog
cursor reset) scans fully and rebuilds the cache; (2) the policy uses
``==``/``!=`` comparisons on age attributes (no well-defined flip instant);
(3) the dirty set outgrew ``incremental_rescan_frac`` of the catalog, where
a scan is cheaper; (4) the caller forces ``matching="full"``. Every full
run with no extra criteria rebuilds the cache in passing. ``RunReport.mode``
records which path ran; correctness contract: all catalog mutations reach
the engine through a subscribed delta source (or ``mark_dirty``).

Incremental state **persists across restarts**: :meth:`save_incremental`
serializes every valid per-policy match table + age-flip schedule (plus any
undrained dirty fids) to a compressed npz beside the catalog's sqlite
mirror, keyed by a signature of each policy's criteria;
:meth:`load_incremental` restores the tables whose signatures still match,
so a restarted engine resumes incrementally instead of paying a cold full
scan. Pair it with a durable changelog subscriber name so deltas that
arrive while the engine is down are re-delivered on restart.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .catalog import Catalog, ColumnBatch
from .changelog import ChangelogHub, ChangelogStream
from .fidtable import FidTable as _FidTable
from .telemetry import slug
from .policy import (AGE_ATTRS, ALWAYS, Cmp, Expr, GLOB_ATTRS, PolicyError,
                     all_of, any_of, attribute_rules, iter_exprs, parse_expr)
from .types import Entry, FsType

Action = Callable[[Entry, dict], bool]   # returns True on success
# Optional vectorized form, attached to the Action callable as the
# ``action_batch`` attribute: (batch, shared params) -> per-entry success.
# ``batch`` is a ColumnBatch unless the callable also sets
# ``needs_entries = True``, in which case the engine materializes and
# passes List[Entry] instead.
BatchAction = Callable[[ColumnBatch, dict], List[bool]]

EVALUATORS = ("numpy", "policy_scan", "policy_scan_mesh")
MATCHING_MODES = ("auto", "full", "incremental")
EXECUTION_MODES = ("columnar", "batched", "scalar")

_ENGINE_SEQ = [0]                 # per-process engine subscriber counter
_ENGINE_SEQ_LOCK = threading.Lock()


@dataclasses.dataclass
class Rule:
    name: str
    condition: Expr
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PolicyDefinition:
    name: str
    action: Action
    scope: Expr = dataclasses.field(default_factory=lambda: ALWAYS)
    rules: List[Rule] = dataclasses.field(default_factory=list)
    # run behaviour
    sort_by: str = "atime"          # LRU by default, like robinhood purge
    sort_desc: bool = False
    max_actions_per_run: int = 0    # 0 = unlimited
    max_volume_per_run: int = 0     # 0 = unlimited (bytes)
    n_threads: int = 1
    dry_run: bool = False
    batch_size: int = 512           # entries per execution chunk
    evaluator: str = "numpy"        # default matching backend
    # whether the action mutates entries (purge/archive/...): actioned fids
    # are then re-marked dirty so incremental state re-observes them. Pure
    # observer actions (tagging nothing, reporting) may set False to keep
    # the dirty set at true churn size.
    mutates: bool = True

    @classmethod
    def from_config(cls, name: str, action: Action, scope: str = "true",
                    rules: Optional[Sequence[Tuple[str, str, dict]]] = None,
                    **kw) -> "PolicyDefinition":
        """Build from string criteria — 'a few lines of configuration'."""
        pd = cls(name=name, action=action, scope=parse_expr(scope), **kw)
        for rname, cond, params in rules or []:
            pd.rules.append(Rule(rname, parse_expr(cond), params))
        return pd


@dataclasses.dataclass
class RunReport:
    policy: str
    matched: int = 0
    succeeded: int = 0
    failed: int = 0
    volume: int = 0          # bytes touched (e.g. freed / archived)
    elapsed: float = 0.0
    trigger: str = "manual"
    matched_volume: int = 0  # total bytes of all matched entries
    skipped: int = 0         # matched but gone from the catalog by exec time
    evaluator: str = "numpy"
    rounds: int = 0          # budget re-planning rounds executed
    mode: str = "full"       # matching path: "full" scan or "incremental"
    reval: int = 0           # rows (re-)evaluated to produce the match set
    execution: str = "columnar"   # execution path that applied the actions
    # why the run did NOT match on the evaluator that was requested ("" =
    # the requested backend ran): benchmarks/CI assert the kernel / mesh
    # path really executed instead of silently degrading to numpy
    fallback_reason: str = ""
    # tiered-residency activity during the mesh match (empty when the run
    # did not go through a device store): counter deltas for demotions /
    # promotions / segments_streamed / windows_streamed / window_stalls
    # etc., plus the absolute resident_groups / demoted_groups gauges —
    # bench_tiering asserts streaming really happened from these
    tiering: dict = dataclasses.field(default_factory=dict)
    # per-run telemetry (empty when the catalog's registry is disabled):
    # {"spans": nested span tree of the whole run — ingest/match/act
    # children, the device store's refresh/launch/combine spans nested
    # inside — "counters": registry counter deltas this run caused}
    telemetry: dict = dataclasses.field(default_factory=dict)


class UsageWatermarkTrigger:
    """Per-resource usage trigger (OST / pool / HBM page pool).

    ``usage_fn()`` returns a list of (resource_key, used, capacity); when
    ``used/capacity`` exceeds ``high_pct``, the policy runs with a target of
    freeing down to ``low_pct``, restricted by ``restrict_fn(resource_key)``.
    """

    def __init__(self, usage_fn: Callable[[], List[Tuple[object, int, int]]],
                 high_pct: float, low_pct: float,
                 restrict_fn: Callable[[object], Expr]) -> None:
        self.usage_fn = usage_fn
        self.high_pct = high_pct
        self.low_pct = low_pct
        self.restrict_fn = restrict_fn

    def check(self) -> List[Tuple[object, Expr, int]]:
        """Returns (resource, extra_criteria, bytes_to_free) per firing."""
        out = []
        for key, used, cap in self.usage_fn():
            if cap <= 0:
                continue
            if 100.0 * used / cap >= self.high_pct:
                target = used - int(cap * self.low_pct / 100.0)
                out.append((key, self.restrict_fn(key), target))
        return out


@dataclasses.dataclass
class _Plan:
    """One execution round: parallel arrays of planned work, sorted order."""
    fids: np.ndarray        # int64
    sizes: np.ndarray       # int64 (match-time snapshot, used for budgets)
    rule_idx: np.ndarray    # int32, -1 = no rule (empty params)


def _age_predicates(policy: PolicyDefinition
                    ) -> Tuple[List[Tuple[str, float]], bool]:
    """Collect (time_column, threshold_seconds) per age predicate in the
    policy's scope/rules; second result is False when a predicate has no
    well-defined flip instant (``==``/``!=`` on a continuous age)."""
    preds: Set[Tuple[str, float]] = set()
    supported = True
    for expr in [policy.scope] + [r.condition for r in policy.rules]:
        for node in iter_exprs(expr):
            if isinstance(node, Cmp) and node.attr in AGE_ATTRS:
                if node.op in ("==", "!="):
                    supported = False
                preds.add((AGE_ATTRS[node.attr], float(node.value)))
    return sorted(preds), supported


def _uses_globs(*exprs: Optional[Expr]) -> bool:
    return any(isinstance(node, Cmp) and node.attr in GLOB_ATTRS
               for expr in exprs if expr is not None
               for node in iter_exprs(expr))


def _next_flips(cols: Dict[str, np.ndarray],
                age_preds: List[Tuple[str, float]], now: float) -> np.ndarray:
    """Earliest future instant each row's age predicates change truth value.

    A predicate over ``time_col`` with threshold T flips exactly at
    ``time_col + T``; instants already past are spent. The boundary itself
    is kept (>= now) so strict comparisons that only become true just after
    the boundary are still re-evaluated on the next run. Rows with no
    future flip read +inf.
    """
    out = np.full(len(cols["fid"]), np.inf)
    for time_col, thr in age_preds:
        cand = np.asarray(cols[time_col], dtype=np.float64) + thr
        np.minimum(out, np.where(cand >= now, cand, np.inf), out=out)
    return out


class _IncrementalState:
    """Per-policy incremental match state (dirty set + cached match table).

    ``matched`` caches every fid satisfying ``scope AND any(rules)`` with
    its budget/sort/attribution columns; ``flips`` schedules time-driven
    re-evaluation for age predicates. ``touched`` collects delta fids
    between runs. All methods are thread-safe against delta fan-in."""

    def __init__(self, policy: PolicyDefinition) -> None:
        self.lock = threading.Lock()
        self.touched: Set[int] = set()
        self.valid = False
        self.sort_by = policy.sort_by
        self.matched = _FidTable((("size", np.int64), ("sort", np.float64),
                                  ("rule", np.int32)))
        self.flips = _FidTable((("flip", np.float64),))
        self.age_preds, self.supported = _age_predicates(policy)
        # string gather is only paid when a criteria holds a glob predicate
        self.needs_strings = _uses_globs(
            policy.scope, *(r.condition for r in policy.rules))
        self.full_rebuilds = 0

    def note_touched(self, fids: Iterable[int]) -> None:
        with self.lock:
            if self.valid:           # invalid state is rebuilt by a full scan
                self.touched.update(fids)

    def drain_touched(self) -> Set[int]:
        with self.lock:
            out, self.touched = self.touched, set()
            return out

    def touched_count(self) -> int:
        with self.lock:
            return len(self.touched)

    def invalidate(self) -> None:
        with self.lock:
            self.valid = False
            self.touched = set()

    def begin_rebuild(self) -> None:
        """Start accepting deltas for the full scan about to be snapshot.

        Called *before* the columnar snapshot: changes committed before the
        snapshot are covered by it, changes committed after will be
        re-delivered into ``touched`` — either way nothing is lost."""
        with self.lock:
            self.touched = set()
            self.valid = True

    def rebuild(self, cols: Dict[str, np.ndarray], mask: np.ndarray,
                rule_idx: np.ndarray, now: float) -> None:
        """Load the cached match table from a full columnar scan."""
        fids = cols["fid"][mask]
        self.matched.bulk_load(
            fids, size=cols["size"][mask],
            sort=np.asarray(cols[self.sort_by][mask], dtype=np.float64),
            rule=rule_idx[mask])
        if self.age_preds:
            flips = _next_flips(cols, self.age_preds, now)
            keep = np.isfinite(flips)
            self.flips.bulk_load(cols["fid"][keep], flip=flips[keep])
        else:
            self.flips.bulk_load(np.zeros(0, dtype=np.int64),
                                 flip=np.zeros(0))
        self.full_rebuilds += 1

    def rebuild_arrays(self, fids: np.ndarray, sizes: np.ndarray,
                       sorts: np.ndarray, rules: np.ndarray,
                       flip_fids: np.ndarray, flips: np.ndarray) -> None:
        """Load the cached match table from pre-extracted flat arrays —
        the mesh full scan's output (``MeshMatch.cache_arrays``), where
        the host columns were never materialized. Same postcondition as
        :meth:`rebuild`: table + flip schedule valid as of the scan."""
        self.matched.bulk_load(
            np.asarray(fids, dtype=np.int64),
            size=np.asarray(sizes, dtype=np.int64),
            sort=np.asarray(sorts, dtype=np.float64),
            rule=np.asarray(rules, dtype=np.int32))
        self.flips.bulk_load(np.asarray(flip_fids, dtype=np.int64),
                             flip=np.asarray(flips, dtype=np.float64))
        self.full_rebuilds += 1

    def due_flips(self, now: float) -> Set[int]:
        return set(self.flips.select_le("flip", now).tolist())

    def apply(self, fids: np.ndarray, cols: Dict[str, np.ndarray],
              present: np.ndarray, mask: np.ndarray, rule_idx: np.ndarray,
              now: float) -> None:
        """Merge re-evaluated rows into the cached tables."""
        gone = fids[~present].tolist()
        self.matched.remove_many(gone)
        self.flips.remove_many(gone)
        hit = mask & present
        self.matched.upsert_many(
            fids[hit].tolist(), size=cols["size"][hit],
            sort=np.asarray(cols[self.sort_by][hit], dtype=np.float64),
            rule=rule_idx[hit])
        self.matched.remove_many(fids[present & ~mask].tolist())
        if self.age_preds:
            flips = _next_flips(cols, self.age_preds, now)
            sched = present & np.isfinite(flips)
            self.flips.upsert_many(fids[sched].tolist(), flip=flips[sched])
            self.flips.remove_many(fids[present & ~np.isfinite(flips)].tolist())
        self.matched.maybe_compact()
        self.flips.maybe_compact()

    def plan_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        fids, cols = self.matched.live()
        return fids, cols["size"], cols["sort"], cols["rule"]

    # -- persistence (engine restart resumes incrementally) -------------------
    def export(self, sig: str) -> Optional[Dict[str, np.ndarray]]:
        """Snapshot the match table + flip schedule (+ undrained dirty fids)
        as flat arrays; None when the state is cold (nothing to resume)."""
        with self.lock:
            if not self.valid:
                return None
            fids, cols = self.matched.live()
            ffids, fcols = self.flips.live()
            return {
                "sig": np.array(sig),
                "fids": fids, "size": cols["size"], "sort": cols["sort"],
                "rule": cols["rule"],
                "flip_fids": ffids, "flip": fcols["flip"],
                "touched": np.array(sorted(self.touched), dtype=np.int64),
            }

    def restore(self, data: Dict[str, np.ndarray]) -> None:
        """Load a previously exported snapshot and mark the state valid."""
        with self.lock:
            self.matched.bulk_load(
                data["fids"].astype(np.int64), size=data["size"],
                sort=data["sort"], rule=data["rule"])
            self.flips.bulk_load(data["flip_fids"].astype(np.int64),
                                 flip=data["flip"])
            self.touched = set(data["touched"].tolist())
            self.valid = True


class PolicyEngine:
    """Evaluates policies over the catalog and applies actions."""

    # auto matching falls back to a full rescan once the dirty set exceeds
    # this fraction of the catalog (a scan is cheaper than that many gathers)
    incremental_rescan_frac = 0.25

    def __init__(self, catalog: Catalog, clock: Callable[[], float] = time.time
                 ) -> None:
        self.catalog = catalog
        self.clock = clock
        self.telemetry = catalog.telemetry
        self._tlabels = {"engine": catalog.telemetry.instance("engine")}
        self.policies: Dict[str, PolicyDefinition] = {}
        self.triggers: List[Tuple[str, UsageWatermarkTrigger]] = []
        self.history: List[RunReport] = []
        self._lock = threading.Lock()
        self._inc: Dict[str, _IncrementalState] = {}
        self._inc_enabled = False
        self._streams: List[Tuple[ChangelogStream, str]] = []
        self._sub_name: Optional[str] = None
        self.device_store = None         # attach_device_store wires the mesh

    def attach_device_store(self, store) -> None:
        """Wire a :class:`~repro.core.device_store.DeviceColumnStore` so the
        ``policy_scan_mesh`` evaluator can match data-parallel over the
        device-resident sharded column stacks (no per-run host concat, no
        host→device re-upload — warm runs refresh churned rows by scatter).
        The store must wrap this engine's catalog."""
        if store.catalog is not self.catalog:
            raise PolicyError("device store wraps a different catalog")
        self.device_store = store

    def register(self, policy: PolicyDefinition) -> None:
        self.policies[policy.name] = policy
        self._inc.pop(policy.name, None)     # definition changed: reset cache
        if self._inc_enabled:
            self._ensure_state(policy.name)

    def add_watermark_trigger(self, policy_name: str,
                              trigger: UsageWatermarkTrigger) -> None:
        self.triggers.append((policy_name, trigger))

    # -- incremental state plumbing ------------------------------------------------
    def _ensure_state(self, policy_name: str) -> Optional[_IncrementalState]:
        state = self._inc.get(policy_name)
        if state is None:
            state = _IncrementalState(self.policies[policy_name])
            if state.supported:
                self._inc[policy_name] = state
            else:
                return None              # ==/!= age predicates: always full
        return state

    def enable_incremental(self) -> None:
        """Create per-policy incremental state; on by default once any delta
        source (pipeline / stream / mark_dirty) is attached."""
        self._inc_enabled = True
        for name in self.policies:
            self._ensure_state(name)

    def subscribe_pipeline(self, pipeline) -> None:
        """Receive (changed, removed) fid deltas from an
        :class:`EventPipeline` after each catalog commit."""
        self.enable_incremental()
        pipeline.add_delta_listener(self._on_deltas)

    def subscribe_stream(self, stream: ChangelogStream,
                         subscriber: Optional[str] = None) -> None:
        """Follow a changelog stream under the engine's own cursor.

        The subscriber registers ``from_start`` so records already emitted
        but not yet committed by the pipeline are not skipped (re-folding
        an already-committed fid is harmless — it is just re-evaluated).
        The engine's cursor then deliberately trails the stream's *default*
        consumer ack watermark (the pipeline's catalog-commit point): a
        record is only folded into dirty state once the catalog reflects
        it. Polling happens at the start of every :meth:`run`.

        ``subscriber`` defaults to a name unique to this engine instance so
        engines sharing a stream never steal each other's records; pass a
        stable name explicitly to resume a durable cursor across restarts
        (and :meth:`ChangelogStream.unsubscribe` it when decommissioned).
        """
        self.enable_incremental()
        name = subscriber or self._subscriber_name()
        # auto-named cursors are per-process: never persisted, so a dead
        # engine instance cannot pin the stream's purge floor after restart
        stream.subscribe(name, from_start=True,
                         durable=subscriber is not None)
        self._streams.append((stream, name))

    def _subscriber_name(self) -> str:
        if self._sub_name is None:
            with _ENGINE_SEQ_LOCK:
                _ENGINE_SEQ[0] += 1
                self._sub_name = f"policy-engine-{_ENGINE_SEQ[0]}"
        return self._sub_name

    def subscribe_hub(self, hub: ChangelogHub,
                      subscriber: Optional[str] = None) -> None:
        for stream in hub.streams.values():
            self.subscribe_stream(stream, subscriber)

    def mark_dirty(self, fids: Iterable[int]) -> None:
        """Explicitly mark entries changed (for catalog mutations that did
        not flow through a subscribed changelog/pipeline)."""
        if not self._inc_enabled:
            self.enable_incremental()
        fids = list(fids)
        for state in list(self._inc.values()):
            state.note_touched(fids)

    def invalidate(self, policy_name: Optional[str] = None) -> None:
        """Drop cached match state (e.g. after a changelog cursor reset);
        the next run falls back to a full scan and rebuilds it."""
        if policy_name is None:
            states = list(self._inc.values())
        else:
            state = self._inc.get(policy_name)
            states = [state] if state is not None else []
        for state in states:
            state.invalidate()

    # -- incremental state persistence --------------------------------------------
    @staticmethod
    def _signature(policy: PolicyDefinition) -> str:
        """Criteria signature guarding resume: a snapshot is only restored
        into a policy whose scope/rules/sort have not changed since save."""
        return repr((policy.scope,
                     [(r.name, r.condition, sorted(r.params.items()))
                      for r in policy.rules],
                     policy.sort_by, policy.sort_desc))

    def _inc_state_path(self, path: Optional[str]) -> str:
        if path is not None:
            return path
        if self.catalog.db_path:
            return self.catalog.db_path + ".incstate.npz"
        raise PolicyError("no incremental-state path: pass one explicitly "
                          "or attach a sqlite mirror to the catalog")

    def save_incremental(self, path: Optional[str] = None) -> str:
        """Serialize every valid per-policy match table + age-flip schedule
        (and undrained dirty fids) beside the sqlite mirror.

        Default path is ``<catalog.db_path>.incstate.npz``. The write is
        atomic (tmp + rename). Call it quiescent — between runs, after the
        changelog pipeline has drained — and pair it with a *durable*
        changelog subscriber so deltas arriving while the engine is down
        are re-delivered after :meth:`load_incremental`.
        """
        path = self._inc_state_path(path)
        payload: Dict[str, np.ndarray] = {}
        for name, state in list(self._inc.items()):
            policy = self.policies.get(name)
            if policy is None:
                continue
            data = state.export(self._signature(policy))
            if data is None:
                continue
            for key, arr in data.items():
                payload[f"{name}::{key}"] = arr
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **payload)
        os.replace(tmp, path)
        return path

    def load_incremental(self, path: Optional[str] = None) -> List[str]:
        """Restore saved match state; returns the policies resumed.

        A policy resumes only when it is registered and its criteria
        signature matches the snapshot (a changed definition falls back to
        the usual cold full scan). Missing file -> no-op, [].
        """
        path = self._inc_state_path(path)
        if not os.path.exists(path):
            return []
        by_policy: Dict[str, Dict[str, np.ndarray]] = {}
        with np.load(path, allow_pickle=False) as z:
            for key in z.files:
                name, field = key.rsplit("::", 1)
                by_policy.setdefault(name, {})[field] = z[key]
        self.enable_incremental()
        resumed = []
        for name, data in by_policy.items():
            policy = self.policies.get(name)
            if policy is None or "sig" not in data:
                continue
            if str(data["sig"]) != self._signature(policy):
                continue
            state = self._ensure_state(name)
            if state is None:
                continue
            state.restore(data)
            resumed.append(name)
        return resumed

    def _on_deltas(self, changed: List[int], removed: List[int]) -> None:
        # called from pipeline worker threads: snapshot against concurrent
        # register() mutating the state dict
        for state in list(self._inc.values()):
            state.note_touched(changed)
            state.note_touched(removed)

    def _poll_streams(self) -> None:
        """Drain subscribed changelog streams into the dirty sets, acking
        only records the default consumer has already committed."""
        for stream, name in self._streams:
            while True:
                recs = stream.read(max_records=4096, subscriber=name)
                if not recs:
                    break
                committed = stream.acked        # pipeline's commit watermark
                use = [r for r in recs if r.seq <= committed]
                if use:
                    fids = [r.fid for r in use]
                    for state in list(self._inc.values()):
                        state.note_touched(fids)
                    stream.ack(use[-1].seq, subscriber=name)
                if len(use) < len(recs):
                    # beyond the commit point: re-deliver on the next poll
                    stream.reset_cursor(subscriber=name)
                    break

    # -- matching -----------------------------------------------------------------
    def _eval_cols(self, policy: PolicyDefinition, cols, extra: Optional[Expr],
                   now: float) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized scope/rules evaluation over any column dict."""
        strings = self.catalog.strings
        mask = policy.scope.mask(cols, strings, now)
        rule_masks = [r.condition.mask(cols, strings, now)
                      for r in policy.rules]
        if rule_masks:
            mask = mask & np.logical_or.reduce(rule_masks)
        if extra is not None:
            mask = mask & extra.mask(cols, strings, now)
        return mask, self._attribute(mask, rule_masks)

    @staticmethod
    def _programs(policy: PolicyDefinition, extra: Optional[Expr]
                  ) -> List[Expr]:
        """[combined criteria] + per-rule conditions, the kernel-path
        program batch shared by the single-launch and mesh evaluators."""
        rule_exprs = [r.condition for r in policy.rules]
        full = all_of([policy.scope]
                      + ([any_of(rule_exprs)] if rule_exprs else [])
                      + ([extra] if extra else []))
        return [full] + rule_exprs

    def _match(self, policy: PolicyDefinition, extra: Optional[Expr],
               now: float, evaluator: str = "numpy"
               ) -> Tuple[np.ndarray, np.ndarray, Dict[str, np.ndarray],
                          str, str]:
        """One columnar pass: final mask + vectorized rule attribution.

        Returns (mask, rule_idx, cols, evaluator_used, fallback_reason).
        ``rule_idx[i]`` is the index of the first (highest-priority) rule
        matching row i, or -1 when the policy has no rules. The
        ``policy_scan`` backend evaluates the whole program batch in a
        single kernel launch with attribution fused on-device; it falls
        back to numpy for host-only (glob) predicates, recording why.
        """
        if evaluator not in EVALUATORS:
            raise PolicyError(f"unknown evaluator {evaluator!r}")
        cols = self.catalog.arrays()
        reason = ""
        if evaluator in ("policy_scan", "policy_scan_mesh"):
            try:
                from ..kernels.policy_scan.ops import match_programs
                masks, _agg, rule_idx = match_programs(
                    cols, self._programs(policy, extra),
                    self.catalog.strings, now)
                return masks[0], rule_idx, cols, "policy_scan", reason
            except PolicyError as e:
                # glob predicates run on the host
                reason = f"policy_scan->numpy: {e}"
        mask, rule_idx = self._eval_cols(policy, cols, extra, now)
        return mask, rule_idx, cols, "numpy", reason

    def _match_mesh(self, policy: PolicyDefinition, extra: Optional[Expr],
                    now: float):
        """Mesh-parallel full match over the attached device store.

        Each device evaluates the (R, P) program batch over its resident
        shard-group column block (stale groups refresh by delta scatter
        first); only matched local rows come back and are translated
        through the store's host mirrors — the catalog columns are never
        concatenated or re-uploaded. Returns the live
        :class:`~repro.core.device_store.MeshMatch` (``plan`` for the
        action plan, ``cache_arrays`` to prime the incremental cache).
        Raises PolicyError when no store is attached or the criteria hold
        host-only (glob) predicates.
        """
        if self.device_store is None:
            raise PolicyError("no device store attached "
                              "(PolicyEngine.attach_device_store)")
        return self.device_store.match(self._programs(policy, extra), now,
                                       with_agg=False)

    def _match_incremental(self, policy: PolicyDefinition,
                           state: _IncrementalState, extra: Optional[Expr],
                           now: float
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray, int]:
        """Re-evaluate only dirty/time-due rows, plan from the cached table.

        Re-evaluated rows flow as a :class:`ColumnBatch` (no Entry
        materialization). Returns (fids, sizes, sort_keys, rule_idx,
        n_revaluated)."""
        reval = sorted(state.drain_touched() | state.due_flips(now))
        if reval:
            try:
                batch = self.catalog.column_batch(
                    reval, with_strings=state.needs_strings)
                mask, rule_idx = self._eval_cols(policy, batch.cols, None,
                                                 now)
                state.apply(np.asarray(reval, dtype=np.int64), batch.cols,
                            batch.present, mask, rule_idx, now)
            except Exception:
                # the drained dirty fids may be partially merged: force a
                # full rebuild rather than silently losing them
                state.invalidate()
                raise
        fids, sizes, sort_keys, rule_idx = state.plan_arrays()
        if extra is not None and fids.size:
            ebatch = self.catalog.column_batch(
                fids.tolist(), with_strings=_uses_globs(extra))
            emask = extra.mask(ebatch.cols, self.catalog.strings, now) \
                & ebatch.present
            fids, sizes = fids[emask], sizes[emask]
            sort_keys, rule_idx = sort_keys[emask], rule_idx[emask]
        return fids, sizes, sort_keys, rule_idx, len(reval)

    def _resolve_matching(self, matching: str, policy: PolicyDefinition,
                          state: Optional[_IncrementalState],
                          has_extra: bool = False) -> str:
        if matching not in MATCHING_MODES:
            raise PolicyError(f"unknown matching mode {matching!r}")
        if matching == "full":
            return "full"
        ready = state is not None and state.valid
        if matching == "incremental":
            if not ready:
                if not _age_predicates(policy)[1]:
                    raise PolicyError(
                        f"policy {policy.name!r} cannot match incrementally:"
                        " ==/!= comparisons on age attributes have no"
                        " well-defined flip instant")
                raise PolicyError(
                    "incremental matching unavailable: no cached match "
                    "state (attach a delta source and run a full scan "
                    "first)")
            return "incremental"
        if not ready:
            return "full"
        limit = self.incremental_rescan_frac * max(1, len(self.catalog))
        if state.touched_count() > limit:
            return "full"                  # scan beats that many gathers
        if has_extra and len(state.matched) > limit:
            # extra criteria re-gather every cached matched fid; past this
            # size a vectorized full snapshot is the cheaper plan
            return "full"
        return "incremental"

    @staticmethod
    def _attribute(mask: np.ndarray, rule_masks: List[np.ndarray]
                   ) -> np.ndarray:
        """First-match-wins rule index per row (shared semantics authority:
        :func:`core.policy.attribute_rules`)."""
        return attribute_rules(rule_masks, int(mask.shape[0]))

    def _rule_params(self, policy: PolicyDefinition, e: Entry, now: float) -> dict:
        for rule in policy.rules:
            if rule.condition.evaluate(e, now):
                return rule.params
        return {}

    # -- execution -----------------------------------------------------------------
    def run(self, policy_name: str, extra_criteria: Optional[Expr] = None,
            target_volume: int = 0, trigger: str = "manual",
            evaluator: Optional[str] = None,
            execution: str = "columnar",
            matching: str = "auto") -> RunReport:
        """One policy run: match -> sort -> apply until targets met.

        ``evaluator`` overrides the policy's matching backend for this run;
        ``execution`` picks the apply path: ``"columnar"`` (default) flows
        ColumnBatch chunks straight to batch actions with zero Entry
        materialization, ``"batched"`` keeps the Entry-materializing
        chunked path and ``"scalar"`` the legacy per-entry path (benchmarks
        / bisection only); ``matching`` picks the planner: ``"full"`` scans
        the catalog columns, ``"incremental"`` re-evaluates only dirty/due
        rows against the cached match table (requires a delta source and a
        prior full run), ``"auto"`` (default) uses the incremental path
        whenever it is valid.
        """
        if execution not in EXECUTION_MODES:
            raise PolicyError(f"unknown execution mode {execution!r}")
        policy = self.policies[policy_name]
        now = self.clock()
        t0 = time.perf_counter()
        c0 = self.telemetry.counter_values() if self.telemetry.enabled \
            else {}
        with self.telemetry.trace("run", policy=policy_name,
                                  trigger=trigger,
                                  **self._tlabels) as _root:
            report = self._run_traced(policy_name, policy, now,
                                      extra_criteria, target_volume,
                                      trigger, evaluator, execution,
                                      matching)
        report.elapsed = time.perf_counter() - t0
        if self.telemetry.enabled:
            c1 = self.telemetry.counter_values()
            report.telemetry = {
                "spans": _root.to_dict(),
                "counters": {k: v - c0.get(k, 0.0)
                             for k, v in c1.items()
                             if v != c0.get(k, 0.0)},
            }
        self.history.append(report)
        return report

    def _run_traced(self, policy_name: str, policy, now: float,
                    extra_criteria: Optional[Expr], target_volume: int,
                    trigger: str, evaluator: Optional[str],
                    execution: str, matching: str) -> RunReport:
        with self.telemetry.trace("run.ingest", **self._tlabels):
            self._poll_streams()
        state = self._inc.get(policy_name)
        mode = self._resolve_matching(matching, policy, state,
                                      has_extra=extra_criteria is not None)

        with self.telemetry.trace("run.match", mode=mode,
                                  **self._tlabels) as _msp:
            (fids, sizes, sort_keys, ridx, reval, used_eval, fallback,
             tiering) = self._match_phase(policy, state, mode,
                                          extra_criteria, now, evaluator)
            _msp.annotate(evaluator=used_eval, reval=reval)
        report = RunReport(policy=policy_name, matched=int(fids.size),
                           trigger=trigger, evaluator=used_eval,
                           mode=mode, reval=reval, execution=execution,
                           fallback_reason=fallback, tiering=tiering,
                           matched_volume=int(sizes.sum()) if fids.size else 0)

        executed = 0
        plan = None
        if fids.size:
            key = -sort_keys if policy.sort_desc else sort_keys
            order = np.lexsort((fids, key))    # fid tie-break: total order,
            plan = _Plan(fids=fids[order],     # identical across planners
                         sizes=sizes[order], rule_idx=ridx[order])
            budget_volume = target_volume or policy.max_volume_per_run
            budget_count = policy.max_actions_per_run
            with self.telemetry.trace("run.act", execution=execution,
                                      **self._tlabels):
                if execution == "scalar":
                    executed = self._run_scalar(policy, plan, now, report,
                                                budget_volume, budget_count)
                else:
                    executed = self._run_batched(policy, plan, now, report,
                                                 budget_volume, budget_count,
                                                 execution)
        if executed and policy.mutates and not policy.dry_run:
            # actions may mutate the catalog directly (purge/archive
            # plugins): re-observe actioned entries on the next run
            acted = plan.fids[:executed].tolist()
            for st in list(self._inc.values()):
                st.note_touched(acted)
        return report

    def _record_fallback(self, reason: str) -> None:
        """Mirror a ``RunReport.fallback_reason`` entry into the registry
        as ``fallback{stage=,reason=}`` — the stage is the downgrade edge
        (``policy_scan_mesh->policy_scan``, ``policy_scan->numpy``, ...),
        the reason a bounded slug of the cause, so exports can assert "no
        silent fallback" without scraping report strings."""
        stage, _, cause = reason.partition(":")
        self.telemetry.counter(
            "fallback", help="evaluator/serving downgrades",
            stage=stage.strip(), reason=slug(cause.strip() or "unknown"),
            **self._tlabels).inc()

    def _match_phase(self, policy: PolicyDefinition, state, mode: str,
                     extra_criteria: Optional[Expr], now: float,
                     evaluator: Optional[str]):
        """Resolve the match set for one run (the ``run.match`` span):
        returns (fids, sizes, sort_keys, ridx, reval, used_eval,
        fallback_reason, tiering_deltas)."""
        fallback = ""
        tiering: dict = {}
        if mode == "incremental":
            fids, sizes, sort_keys, ridx, reval = self._match_incremental(
                policy, state, extra_criteria, now)
            used_eval = "numpy"
            want = evaluator or policy.evaluator
            if want != "numpy":
                # not a degradation — the cached match table beat a full
                # scan on ANY backend — but still recorded so callers
                # asserting "the kernel path ran" see why it did not
                fallback = (f"{want}->incremental: cached match table "
                            "served the run (force matching=\"full\" to "
                            "exercise the evaluator)")
                self._record_fallback(fallback)
        else:
            want = evaluator or policy.evaluator
            mesh_done = False
            if want == "policy_scan_mesh":
                # the mesh full scan primes the incremental cache without
                # touching host columns: matched rows + age-flip instants
                # extract from the store's host mirrors (cache_arrays),
                # same no-lost-deltas bracket as the host scans below
                rebuild = state is not None and extra_criteria is None
                if rebuild:
                    state.begin_rebuild()
                tc0 = self.device_store.tiering_counters() \
                    if self.device_store is not None else {}
                try:
                    match = self._match_mesh(policy, extra_criteria, now)
                    if rebuild:
                        (fids, sizes, sort_keys, ridx, flip_fids,
                         flips) = match.cache_arrays(
                            policy.sort_by, state.age_preds, now)
                        state.rebuild_arrays(fids, sizes, sort_keys, ridx,
                                             flip_fids, flips)
                    else:
                        fids, sizes, sort_keys, ridx = match.plan(
                            policy.sort_by)
                    reval = match.reval
                    used_eval = "policy_scan_mesh"
                    mesh_done = True
                    # deltas for counters, absolute values for gauges
                    tc1 = self.device_store.tiering_counters()
                    tiering = {
                        k: v if k in ("resident_groups", "demoted_groups")
                        else v - tc0.get(k, 0) for k, v in tc1.items()}
                except PolicyError as e:
                    if rebuild:
                        state.invalidate()
                    fallback = f"policy_scan_mesh->policy_scan: {e}"
                    self._record_fallback(fallback)
                except Exception:
                    if rebuild:
                        state.invalidate()
                    raise
            if not mesh_done:
                rebuild = state is not None and extra_criteria is None
                if rebuild:
                    state.begin_rebuild()   # before snapshot: no lost deltas
                try:
                    mask, rule_idx, cols, used_eval, reason = self._match(
                        policy, extra_criteria, now, want)
                    if reason:
                        self._record_fallback(reason)
                    fallback = "; ".join(r for r in (fallback, reason) if r)
                    fids = cols["fid"][mask]
                    sizes = cols["size"][mask]
                    ridx = rule_idx[mask]
                    sort_keys = np.asarray(cols[policy.sort_by][mask],
                                           dtype=np.float64)
                    reval = int(mask.size)
                    if rebuild:
                        state.rebuild(cols, mask, rule_idx, now)
                except Exception:
                    # never leave a half-built cache marked valid (a bad
                    # sort_by would otherwise silently match nothing forever)
                    if rebuild:
                        state.invalidate()
                    raise
        return fids, sizes, sort_keys, ridx, reval, used_eval, fallback, \
            tiering

    # -- batched / columnar execution ---------------------------------------------
    def _run_batched(self, policy: PolicyDefinition, plan: _Plan, now: float,
                     report: RunReport, budget_volume: int,
                     budget_count: int, execution: str = "columnar") -> int:
        """Budgeted rounds of chunk-parallel execution.

        Each round takes the minimal prefix of the remaining sorted work
        whose projected (match-time) volume/count meets the remaining
        budget, so the stop decision happens on batch boundaries and the
        actioned set never depends on thread timing. A follow-up round only
        happens when failures/skips left a budget unmet. Returns the number
        of plan entries attempted.
        """
        n = len(plan.fids)
        pos = 0
        while pos < n:
            take = n - pos
            if budget_volume:
                remaining = budget_volume - report.volume
                if remaining <= 0:
                    break
                csum = np.cumsum(plan.sizes[pos:])
                take = min(take, int(np.searchsorted(csum, remaining)) + 1)
            if budget_count:
                remaining_n = budget_count - report.succeeded
                if remaining_n <= 0:
                    break
                take = min(take, remaining_n)
            self._execute_round(policy, plan, pos, pos + take, now, report,
                                execution)
            report.rounds += 1
            pos += take
            if not budget_volume and not budget_count:
                break                      # single round covers everything
        return pos

    def _execute_round(self, policy: PolicyDefinition, plan: _Plan,
                       lo: int, hi: int, now: float, report: RunReport,
                       execution: str = "columnar") -> None:
        """Execute plan[lo:hi] in chunks drawn from a deque by N workers."""
        chunk = max(1, policy.batch_size)
        work: "deque[slice]" = deque(slice(i, min(i + chunk, hi))
                                     for i in range(lo, hi, chunk))

        def worker() -> None:
            while True:
                try:
                    sl = work.popleft()    # atomic; IndexError ends worker
                except IndexError:
                    return
                self._apply_chunk(policy, plan, sl, now, report, execution)

        n_threads = min(max(1, policy.n_threads), len(work))
        if n_threads <= 1:
            worker()
            return
        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _apply_chunk(self, policy: PolicyDefinition, plan: _Plan,
                     sl: slice, now: float, report: RunReport,
                     execution: str = "columnar") -> None:
        """Apply one chunk of planned work.

        ``execution="columnar"`` (the hot path) fetches the chunk as a
        :class:`ColumnBatch` — one numeric gather per shard group, zero
        ``Entry.__init__`` — and hands per-rule sub-batches to the action's
        batch interface. Entries are materialized only when the action
        declares ``needs_entries = True`` or exposes no batch interface.
        ``execution="batched"`` is the legacy baseline: every chunk
        materializes Entries first, then batch actions run off a
        ``ColumnBatch.from_entries`` shim (identical plugin code, so the
        two paths action identical fid sequences — the materialization is
        exactly the cost being measured).
        """
        fids = plan.fids[sl]
        sizes = plan.sizes[sl]
        ridx = plan.rule_idx[sl]
        if policy.dry_run:
            with self._lock:
                report.succeeded += len(fids)
                report.volume += int(sizes.sum())
            return
        batch_fn: Optional[BatchAction] = getattr(policy.action,
                                                  "action_batch", None)
        needs_entries = bool(getattr(policy.action, "needs_entries", False))
        entries: Optional[List[Optional[Entry]]] = None
        batch: Optional[ColumnBatch] = None
        if batch_fn is None or needs_entries or execution == "batched":
            entries = self.catalog.get_batch(fids.tolist())
            skipped = np.array([e is None for e in entries])
            if batch_fn is not None and not needs_entries:
                batch = ColumnBatch.from_entries(entries,
                                                 self.catalog.strings,
                                                 self.catalog)
        else:
            batch = self.catalog.column_batch(fids.tolist())
            skipped = ~batch.present
        ok = np.zeros(len(fids), dtype=bool)
        if batch_fn is not None:
            # batch interface: one call per rule group (shared params)
            for ri in np.unique(ridx):
                group = np.nonzero((ridx == ri) & ~skipped)[0]
                if not group.size:
                    continue
                params = policy.rules[ri].params if ri >= 0 else {}
                payload = ([entries[i] for i in group] if needs_entries
                           else batch.take(group))
                try:
                    results = batch_fn(payload, params)
                except Exception:
                    results = [False] * int(group.size)
                ok[group] = results
        else:
            # scalar actions keep strict plan (sort) order within the chunk
            for i in np.nonzero(~skipped)[0]:
                ri = ridx[i]
                params = policy.rules[ri].params if ri >= 0 else {}
                try:
                    ok[i] = policy.action(entries[i], params)
                except Exception:
                    ok[i] = False
        done = ok & ~skipped
        with self._lock:
            report.succeeded += int(done.sum())
            report.failed += int((~ok & ~skipped).sum())
            report.skipped += int(skipped.sum())
            report.volume += int(sizes[done].sum())

    # -- legacy scalar execution (benchmark baseline) ------------------------------
    def _run_scalar(self, policy: PolicyDefinition, plan: _Plan, now: float,
                    report: RunReport, budget_volume: int,
                    budget_count: int) -> int:
        """Pre-batching hot path: O(n) dequeues, per-entry catalog.get and
        Python rule re-evaluation, racy post-hoc budget checks. Returns the
        number of plan entries attempted (conservative: the whole list)."""
        work = list(plan.fids.tolist())
        work_lock = threading.Lock()
        stop = threading.Event()

        def runner() -> None:
            while not stop.is_set():
                with work_lock:
                    if not work:
                        return
                    fid = work.pop(0)
                e = self.catalog.get(fid)
                if e is None:
                    continue
                params = self._rule_params(policy, e, now)
                size = e.size
                if policy.dry_run:
                    ok = True
                else:
                    try:
                        ok = policy.action(e, params)
                    except Exception:
                        ok = False
                with self._lock:
                    if ok:
                        report.succeeded += 1
                        report.volume += size
                    else:
                        report.failed += 1
                    if budget_volume and report.volume >= budget_volume:
                        stop.set()
                    if budget_count and report.succeeded >= budget_count:
                        stop.set()

        threads = [threading.Thread(target=runner, daemon=True)
                   for _ in range(max(1, policy.n_threads))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return len(plan.fids)

    def check_triggers(self) -> List[RunReport]:
        """Fire any watermark triggers whose threshold is exceeded (C7)."""
        reports = []
        for policy_name, trig in self.triggers:
            for key, extra, target in trig.check():
                reports.append(self.run(policy_name, extra_criteria=extra,
                                        target_volume=target,
                                        trigger=f"watermark:{key}"))
        return reports

    def run_all_periodic(self) -> List[RunReport]:
        return [self.run(name) for name in self.policies]
