"""The model zoo's single backbone: pattern-scanned transformer/hybrid LM.

One implementation covers all 10 assigned architectures through
:class:`~repro.models.config.ModelConfig`:

* layers are grouped into complete pattern repetitions executed with
  ``jax.lax.scan`` over stacked parameters (HLO size independent of depth,
  which keeps 512-device GSPMD compiles fast), plus an unrolled tail for
  depths not divisible by the pattern period (recurrentgemma: 38 = 12*3+2);
* every scanned superblock is wrapped in ``jax.checkpoint`` (full remat) so
  train-step activation memory is O(sqrt-ish) instead of O(depth);
* decode caches mirror the parameter grouping so the same scan drives
  single-token serving steps.

Functions are pure; ``Model`` only holds the config.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import rwkv6 as rk
from .components import (attention, causal_conv1d, gelu_mlp, layer_norm,
                         moe_forward, rglru_scan, rglru_step, rms_norm, rope,
                         softcap, swiglu, _rglru_gates)
from .config import (ATTN_FULL, ATTN_LOCAL, ATTN_NONCAUSAL, FFN_DENSE,
                     FFN_MOE, MIX_RGLRU, MIX_RWKV6, LayerSpec, ModelConfig)

PyTree = Any
_MOE_AUX_COEF = 0.01


# ===========================================================================
# Parameter initialization
# ===========================================================================

def _norm_params(cfg: ModelConfig, key) -> PyTree:
    if cfg.norm == "ln":
        return {"w": jnp.ones(cfg.d_model, jnp.bfloat16),
                "b": jnp.zeros(cfg.d_model, jnp.bfloat16)}
    return {"w": jnp.zeros(cfg.d_model, jnp.bfloat16)}


def _dense(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(
        jnp.bfloat16)


def _attn_params(cfg: ModelConfig, key, cross: bool = False) -> PyTree:
    D = cfg.d_model
    qk = cfg.n_heads * cfg.head_dim
    kv = cfg.n_kv * cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": _dense(ks[0], (D, qk)),
        "wk": _dense(ks[1], (D, kv)),
        "wv": _dense(ks[2], (D, kv)),
        "wo": _dense(ks[3], (qk, D), scale=1.0 / math.sqrt(qk)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros(qk, jnp.bfloat16)
        p["bk"] = jnp.zeros(kv, jnp.bfloat16)
        p["bv"] = jnp.zeros(kv, jnp.bfloat16)
    if cross:
        p["gate"] = jnp.zeros((), jnp.bfloat16)   # llama3.2-vision gating
    return p


def _ffn_params(cfg: ModelConfig, key, spec: LayerSpec) -> PyTree:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    if spec.mix == MIX_RWKV6:
        # rwkv channel-mix
        return {"mu_r": jnp.zeros(D, jnp.bfloat16),
                "mu_k": jnp.zeros(D, jnp.bfloat16),
                "wr": _dense(ks[0], (D, D)),
                "wk": _dense(ks[1], (D, F)),
                "wv": _dense(ks[2], (F, D))}
    if spec.ffn == FFN_MOE:
        assert cfg.moe is not None
        E = cfg.moe.num_experts
        p = {"router": _dense(ks[0], (D, E), scale=0.02),
             "w1": _dense(ks[1], (E, D, F), scale=1.0 / math.sqrt(D)),
             "w3": _dense(ks[2], (E, D, F), scale=1.0 / math.sqrt(D)),
             "w2": _dense(ks[3], (E, F, D), scale=1.0 / math.sqrt(F))}
        if cfg.moe.shared_expert:
            p["s1"] = _dense(ks[4], (D, F))
            p["s3"] = _dense(ks[5], (D, F))
            p["s2"] = _dense(ks[6], (F, D))
        return p
    if cfg.ffn_act == "gelu":
        return {"w1": _dense(ks[0], (D, F)), "b1": jnp.zeros(F, jnp.bfloat16),
                "w2": _dense(ks[1], (F, D)), "b2": jnp.zeros(D, jnp.bfloat16)}
    return {"w1": _dense(ks[0], (D, F)), "w3": _dense(ks[1], (D, F)),
            "w2": _dense(ks[2], (F, D))}


def _rglru_params(cfg: ModelConfig, key) -> PyTree:
    D, R = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "w_gate": _dense(ks[0], (D, R)),
        "w_in": _dense(ks[1], (D, R)),
        "conv_w": _dense(ks[2], (cfg.conv_width, R), scale=0.3),
        "w_a": _dense(ks[3], (R, R)),
        "b_a": jnp.zeros(R, jnp.float32),
        "w_x": _dense(ks[4], (R, R)),
        "b_x": jnp.zeros(R, jnp.float32),
        "lam": jnp.full((R,), -4.35, jnp.float32),   # a ~ 0.95 at r=0.5
        "w_out": _dense(ks[5], (R, D)),
    }


def _rwkv_params(cfg: ModelConfig, key) -> PyTree:
    D = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    L, L2 = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    ks = jax.random.split(key, 10)
    return {
        "mu": jnp.zeros((5, D), jnp.bfloat16),           # r,k,v,g,w lerp base
        "maa_a": _dense(ks[0], (D, 5 * L), scale=0.01),
        "maa_b": (jax.random.normal(ks[1], (5, L, D)) * 0.01).astype(jnp.bfloat16),
        "wr": _dense(ks[2], (D, D)),
        "wk": _dense(ks[3], (D, D)),
        "wv": _dense(ks[4], (D, D)),
        "wg": _dense(ks[5], (D, D)),
        "w0": jnp.full((D,), -3.9, jnp.float32),         # base decay ~0.98
        "wd_a": _dense(ks[6], (D, L2), scale=0.01),
        "wd_b": (jax.random.normal(ks[7], (L2, D)) * 0.01).astype(jnp.bfloat16),
        "u": (jax.random.normal(ks[8], (H, hd)) * 0.02).astype(jnp.float32),
        "gn_w": jnp.ones(D, jnp.bfloat16),
        "wo": _dense(ks[9], (D, D)),
    }


def _layer_params(cfg: ModelConfig, spec: LayerSpec, key) -> PyTree:
    ks = jax.random.split(key, 5)
    p: Dict[str, PyTree] = {"ln1": _norm_params(cfg, ks[0]),
                            "ln2": _norm_params(cfg, ks[1])}
    if cfg.post_norms:
        p["ln1p"] = _norm_params(cfg, ks[0])
        p["ln2p"] = _norm_params(cfg, ks[1])
    if spec.mix in (ATTN_FULL, ATTN_LOCAL, ATTN_NONCAUSAL):
        p["attn"] = _attn_params(cfg, ks[2])
    elif spec.mix == MIX_RGLRU:
        p["rglru"] = _rglru_params(cfg, ks[2])
    elif spec.mix == MIX_RWKV6:
        p["rwkv"] = _rwkv_params(cfg, ks[2])
    if spec.cross_attn:
        p["lnx"] = _norm_params(cfg, ks[3])
        p["xattn"] = _attn_params(cfg, ks[3], cross=True)
    p["ffn"] = _ffn_params(cfg, ks[4], spec)
    return p


# ===========================================================================
# Layer application (sequence mode and step mode share sublayer helpers)
# ===========================================================================

def _norm(cfg: ModelConfig, p: PyTree, x: jax.Array) -> jax.Array:
    if cfg.norm == "ln":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


def _qkv(cfg: ModelConfig, p: PyTree, x: jax.Array, n_q: int, n_kv: int
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, n_q, hd), k.reshape(B, S, n_kv, hd),
            v.reshape(B, S, n_kv, hd))


def _self_attn_seq(cfg: ModelConfig, spec: LayerSpec, p: PyTree,
                   x: jax.Array, positions: jax.Array,
                   kv_chunk: int, unroll: int = 1
                   ) -> Tuple[jax.Array, PyTree]:
    """Full-sequence self attention; returns (out, kv-for-cache)."""
    q, k, v = _qkv(cfg, p, x, cfg.n_heads, cfg.n_kv)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    causal = spec.mix != ATTN_NONCAUSAL
    window = cfg.window if spec.mix == ATTN_LOCAL else 0
    out = attention(q, k, v, q_pos=positions, kv_pos=positions,
                    causal=causal, window=window,
                    logit_softcap=cfg.attn_softcap, kv_chunk=kv_chunk,
                    unroll=unroll)
    B, S, _, _ = out.shape
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def _cross_attn(cfg: ModelConfig, p: PyTree, x: jax.Array,
                xk: jax.Array, xv: jax.Array, kv_chunk: int) -> jax.Array:
    """Cross attention to precomputed source K/V (no positions, no mask)."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    src_len = xk.shape[1]
    kv_pos = jnp.arange(src_len)
    q_pos = jnp.full((S,), src_len, dtype=jnp.int32)  # attend to everything
    out = attention(q, xk, xv, q_pos=q_pos, kv_pos=kv_pos, causal=False,
                    kv_chunk=kv_chunk)
    out = out.reshape(B, S, -1) @ p["wo"]
    if "gate" in p:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out


def _source_kv(cfg: ModelConfig, p: PyTree, src: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = src.shape
    xk = (src @ p["wk"]).reshape(B, T, cfg.n_kv, cfg.head_dim)
    xv = (src @ p["wv"]).reshape(B, T, cfg.n_kv, cfg.head_dim)
    return xk, xv


def _ffn_apply(cfg: ModelConfig, spec: LayerSpec, p: PyTree, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, moe_aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if spec.mix == MIX_RWKV6:
        xprev = rk.token_shift(x)
        mr = x + p["mu_r"] * (xprev - x)
        mk = x + p["mu_k"] * (xprev - x)
        kk = jnp.square(jax.nn.relu(mk @ p["wk"]))
        return jax.nn.sigmoid(mr @ p["wr"]) * (kk @ p["wv"]), zero
    if spec.ffn == FFN_MOE:
        shared = (p["s1"], p["s3"], p["s2"]) if "s1" in p else None
        out, aux = moe_forward(x, p["router"], p["w1"], p["w3"], p["w2"],
                               cfg.moe, shared, groups=cfg.moe_groups,
                               buf_pspec=cfg.moe_pspec)
        return out, aux
    if cfg.ffn_act == "gelu":
        return gelu_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"]), zero
    return swiglu(x, p["w1"], p["w3"], p["w2"]), zero


def _rwkv_timemix_prep(cfg: ModelConfig, p: PyTree, x: jax.Array,
                       xprev: jax.Array):
    """Shared r,k,v,g,lw computation for seq and step modes (f32 outputs)."""
    B = x.shape[0]
    S = x.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim
    L = cfg.rwkv_lora_mix
    dx = xprev - x
    dyn = jnp.tanh(dx @ p["maa_a"])                     # (B,S,5L)
    dyn = dyn.reshape(B, S, 5, L)
    mixes = []
    for i in range(5):
        m = x + (p["mu"][i] + jnp.einsum("bsl,ld->bsd", dyn[:, :, i],
                                         p["maa_b"][i])) * dx
        mixes.append(m)
    mr, mk, mv, mg, mw = mixes
    r = (mr @ p["wr"]).astype(jnp.float32).reshape(B, S, H, hd)
    k = (mk @ p["wk"]).astype(jnp.float32).reshape(B, S, H, hd)
    v = (mv @ p["wv"]).astype(jnp.float32).reshape(B, S, H, hd)
    g = mg @ p["wg"]
    dd = jnp.tanh(mw @ p["wd_a"]) @ p["wd_b"]           # (B,S,D)
    lw = -jnp.exp(p["w0"] + dd.astype(jnp.float32))      # log decay <= 0
    lw = lw.reshape(B, S, H, hd)
    return r, k, v, g, lw


def _rwkv_out(cfg: ModelConfig, p: PyTree, y: jax.Array, g: jax.Array,
              B: int, S: int) -> jax.Array:
    """Per-head group-norm + silu gate + output proj."""
    D = cfg.d_model
    yf = y.reshape(B, S, cfg.n_heads, cfg.head_dim)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    yf = yf.reshape(B, S, D) * p["gn_w"].astype(jnp.float32)
    out = (yf.astype(g.dtype) * jax.nn.silu(g)) @ p["wo"]
    return out


def apply_layer_seq(cfg: ModelConfig, spec: LayerSpec, p: PyTree,
                    x: jax.Array, positions: jax.Array,
                    extras: Optional[dict] = None, kv_chunk: int = 1024,
                    want_cache: bool = False, unroll: int = 1
                    ) -> Tuple[jax.Array, jax.Array, PyTree]:
    """One layer over a full sequence. Returns (x, aux_loss, cache_blob)."""
    B, S, D = x.shape
    blob: Dict[str, jax.Array] = {}
    h = _norm(cfg, p["ln1"], x)

    if spec.mix in (ATTN_FULL, ATTN_LOCAL, ATTN_NONCAUSAL):
        out, (k, v) = _self_attn_seq(cfg, spec, p["attn"], h, positions,
                                     kv_chunk, unroll)
        if want_cache:
            blob["k"], blob["v"] = k, v
    elif spec.mix == MIX_RGLRU:
        rp = p["rglru"]
        gate = jax.nn.gelu(h @ rp["w_gate"])
        vin = h @ rp["w_in"]
        vin, conv_state = causal_conv1d(vin, rp["conv_w"])
        log_a, b = _rglru_gates(vin, rp)
        hseq = rglru_scan(log_a, b)                      # (B,S,R) f32
        out = (gate * hseq.astype(gate.dtype)) @ rp["w_out"]
        if want_cache:
            blob["h"] = hseq[:, -1, :]
            blob["conv"] = conv_state
    elif spec.mix == MIX_RWKV6:
        rp = p["rwkv"]
        xprev = rk.token_shift(h)
        r, k, v, g, lw = _rwkv_timemix_prep(cfg, rp, h, xprev)
        chunk = 64 if S % 64 == 0 else (math.gcd(S, 64) or S)
        y, st = rk.wkv_chunked(r, k, v, lw, rp["u"], chunk=chunk,
                               unroll=unroll)
        out = _rwkv_out(cfg, rp, y, g, B, S)
        if want_cache:
            blob["s"] = st
            blob["shift_t"] = h[:, -1, :]
    else:
        raise ValueError(spec.mix)

    if cfg.post_norms:
        out = _norm(cfg, p["ln1p"], out)
    x = x + out

    if spec.cross_attn:
        assert extras is not None and "src" in extras, \
            "cross-attn layer needs extras['src']"
        hx = _norm(cfg, p["lnx"], x)
        xk, xv = _source_kv(cfg, p["xattn"], extras["src"])
        x = x + _cross_attn(cfg, p["xattn"], hx, xk, xv, kv_chunk)
        if want_cache:
            blob["xk"], blob["xv"] = xk, xv

    h2 = _norm(cfg, p["ln2"], x)
    if spec.mix == MIX_RWKV6 and want_cache:
        blob["shift_c"] = h2[:, -1, :]
    out2, aux = _ffn_apply(cfg, spec, p["ffn"], h2)
    if cfg.post_norms:
        out2 = _norm(cfg, p["ln2p"], out2)
    x = x + out2
    return x, aux, blob


# ---------------------------------------------------------------------------
# Decode step (x: (B, 1, D))
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     cache_len: int, abstract: bool = False) -> PyTree:
    """Cache blob for one layer. cache_len caps local windows."""
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda s, d: jnp.zeros(s, d)))
    hd = cfg.head_dim
    quant = cfg.kv_cache_dtype == "int8"
    kv_dt = jnp.int8 if quant else jnp.bfloat16
    blob: Dict[str, Any] = {}
    if spec.mix in (ATTN_FULL, ATTN_NONCAUSAL):
        blob["k"] = mk((batch, cache_len, cfg.n_kv, hd), kv_dt)
        blob["v"] = mk((batch, cache_len, cfg.n_kv, hd), kv_dt)
        if quant:
            blob["kscale"] = mk((batch, cache_len, cfg.n_kv, 1), jnp.float32)
            blob["vscale"] = mk((batch, cache_len, cfg.n_kv, 1), jnp.float32)
    elif spec.mix == ATTN_LOCAL:
        assert not quant, "int8 KV supports full caches only (no rings yet)"
        L = min(cache_len, cfg.window)
        blob["k"] = mk((batch, L, cfg.n_kv, hd), jnp.bfloat16)
        blob["v"] = mk((batch, L, cfg.n_kv, hd), jnp.bfloat16)
    elif spec.mix == MIX_RGLRU:
        blob["h"] = mk((batch, cfg.rnn_width), jnp.float32)
        blob["conv"] = mk((batch, cfg.conv_width - 1, cfg.rnn_width),
                          jnp.bfloat16)
    elif spec.mix == MIX_RWKV6:
        blob["s"] = mk((batch, cfg.n_heads, hd, hd), jnp.float32)
        blob["shift_t"] = mk((batch, cfg.d_model), jnp.bfloat16)
        blob["shift_c"] = mk((batch, cfg.d_model), jnp.bfloat16)
    if spec.cross_attn:
        src_len = cfg.n_img_tokens or (cfg.encoder.n_frames if cfg.encoder
                                       else 0)
        blob["xk"] = mk((batch, src_len, cfg.n_kv, hd), jnp.bfloat16)
        blob["xv"] = mk((batch, src_len, cfg.n_kv, hd), jnp.bfloat16)
    return blob


def _quantize_kv(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 quantization. t: (B, S, K, hd)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale


def apply_layer_step(cfg: ModelConfig, spec: LayerSpec, p: PyTree,
                     cache: PyTree, x: jax.Array, pos: jax.Array,
                     unroll: int = 1) -> Tuple[jax.Array, PyTree]:
    """One decode token. x: (B,1,D); pos: scalar int32 (current position)."""
    B = x.shape[0]
    hd = cfg.head_dim
    new_cache = dict(cache)
    h = _norm(cfg, p["ln1"], x)

    if spec.mix in (ATTN_FULL, ATTN_LOCAL, ATTN_NONCAUSAL):
        ap = p["attn"]
        q, k, v = _qkv(cfg, ap, h, cfg.n_heads, cfg.n_kv)
        posv = pos[None] if pos.ndim == 0 else pos
        q = rope(q, posv, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, posv, cfg.rope_theta, cfg.rope_fraction)
        L = cache["k"].shape[1]
        slot = jnp.mod(pos, L) if spec.mix == ATTN_LOCAL else \
            jnp.minimum(pos, L - 1)
        if "kscale" in cache:      # int8 quantized cache
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            upd = lambda c, u: jax.lax.dynamic_update_slice_in_dim(
                c, u, slot, axis=1)
            new_cache["k"] = upd(cache["k"], kq)
            new_cache["v"] = upd(cache["v"], vq)
            new_cache["kscale"] = upd(cache["kscale"], ks)
            new_cache["vscale"] = upd(cache["vscale"], vs)
            ck = (new_cache["k"].astype(jnp.bfloat16)
                  * new_cache["kscale"].astype(jnp.bfloat16))
            cv = (new_cache["v"].astype(jnp.bfloat16)
                  * new_cache["vscale"].astype(jnp.bfloat16))
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=1)
            new_cache["k"], new_cache["v"] = ck, cv
        idx = jnp.arange(L)
        if spec.mix == ATTN_LOCAL:
            kv_pos = pos - jnp.mod(pos - idx, L)
            kv_pos = jnp.where(kv_pos >= 0, kv_pos, -1)
        else:
            kv_pos = jnp.where(idx <= pos, idx, -1)
        window = cfg.window if spec.mix == ATTN_LOCAL else 0
        out = attention(q, ck, cv, q_pos=posv, kv_pos=kv_pos, causal=True,
                        window=window, logit_softcap=cfg.attn_softcap,
                        kv_chunk=1024 if L % 1024 == 0 else L,
                        unroll=unroll)
        out = out.reshape(B, 1, -1) @ ap["wo"]
    elif spec.mix == MIX_RGLRU:
        rp = p["rglru"]
        gate = jax.nn.gelu(h @ rp["w_gate"])
        vin = h @ rp["w_in"]
        vin2, conv_state = causal_conv1d(vin, rp["conv_w"],
                                         state=cache["conv"])
        log_a, b = _rglru_gates(vin2[:, 0, :], rp)
        h_new = rglru_step(log_a, b, cache["h"])
        new_cache["h"], new_cache["conv"] = h_new, conv_state
        out = (gate[:, 0] * h_new.astype(gate.dtype)) @ rp["w_out"]
        out = out[:, None, :]
    elif spec.mix == MIX_RWKV6:
        rp = p["rwkv"]
        xprev = cache["shift_t"][:, None, :].astype(h.dtype)
        r, k, v, g, lw = _rwkv_timemix_prep(cfg, rp, h, xprev)
        y, s_new = rk.wkv_step(r[:, 0], k[:, 0], v[:, 0],
                               jnp.exp(lw[:, 0]), rp["u"], cache["s"])
        new_cache["s"] = s_new
        new_cache["shift_t"] = h[:, 0, :]
        out = _rwkv_out(cfg, rp, y[:, None], g, B, 1)
    else:
        raise ValueError(spec.mix)

    if cfg.post_norms:
        out = _norm(cfg, p["ln1p"], out)
    x = x + out

    if spec.cross_attn:
        hx = _norm(cfg, p["lnx"], x)
        x = x + _cross_attn(cfg, p["xattn"], hx, cache["xk"], cache["xv"],
                            kv_chunk=1 << 16)

    h2 = _norm(cfg, p["ln2"], x)
    if spec.mix == MIX_RWKV6:
        xprev_c = cache["shift_c"][:, None, :].astype(h2.dtype)
        fp = p["ffn"]
        mr = h2 + fp["mu_r"] * (xprev_c - h2)
        mk2 = h2 + fp["mu_k"] * (xprev_c - h2)
        kk = jnp.square(jax.nn.relu(mk2 @ fp["wk"]))
        out2 = jax.nn.sigmoid(mr @ fp["wr"]) * (kk @ fp["wv"])
        new_cache["shift_c"] = h2[:, 0, :]
    else:
        out2, _ = _ffn_apply(cfg, spec, p["ffn"], h2)
    if cfg.post_norms:
        out2 = _norm(cfg, p["ln2p"], out2)
    return x + out2, new_cache


# ===========================================================================
# Whisper-style encoder
# ===========================================================================

def _encoder_params(cfg: ModelConfig, key) -> PyTree:
    enc = cfg.encoder
    ks = jax.random.split(key, enc.n_layers + 2)
    spec = LayerSpec(mix=ATTN_NONCAUSAL, ffn=FFN_DENSE)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_layer_params(cfg, spec, ks[i]) for i in range(enc.n_layers)])
    return {"pos": (jax.random.normal(ks[-2], (enc.n_frames, cfg.d_model))
                    * 0.01).astype(jnp.bfloat16),
            "layers": stacked,
            "final": _norm_params(cfg, ks[-1])}


def encode(cfg: ModelConfig, p: PyTree, frames: jax.Array,
           kv_chunk: int = 1024, unroll_layers: bool = False,
           inner_unroll: int = 1) -> jax.Array:
    """frames: (B, n_frames, D) stubbed conv-frontend output."""
    spec = LayerSpec(mix=ATTN_NONCAUSAL, ffn=FFN_DENSE)
    x = frames + p["pos"][None]
    positions = jnp.arange(frames.shape[1])

    @jax.checkpoint
    def body(x, lp):
        x, _, _ = apply_layer_seq(cfg, spec, lp, x, positions,
                                  kv_chunk=kv_chunk, unroll=inner_unroll)
        return x, None

    if unroll_layers:
        n = cfg.encoder.n_layers
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[i], p["layers"]))
    else:
        x, _ = jax.lax.scan(body, x, p["layers"])
    return _norm(cfg, p["final"], x)


# ===========================================================================
# Model facade
# ===========================================================================

@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    kv_chunk: int = 1024
    # analysis knobs (see launch/dryrun.py): unroll all loops so XLA
    # cost_analysis counts every iteration (while bodies are counted once)
    unroll_layers: bool = False
    inner_unroll: int = 1
    # activation rematerialization: "full" (recompute everything in bwd)
    # or "dots" (save matmul outputs, recompute only elementwise — SPerf)
    remat_policy: str = "full"
    # optional PartitionSpec for the (B, S, V) logits (avoids a replicated
    # vocab-sized buffer for tied-embedding archs)
    logits_pspec: Any = None

    # -- params ---------------------------------------------------------------
    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        n_keys = 4 + cfg.n_super + len(cfg.tail_specs)
        ks = jax.random.split(key, n_keys)
        params: Dict[str, PyTree] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model))
                      * 0.02).astype(jnp.bfloat16),
            "final": _norm_params(cfg, ks[1]),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = _dense(ks[2], (cfg.d_model, cfg.vocab),
                                       scale=0.02)
        period = len(cfg.pattern)
        if cfg.n_super > 0:
            stacks = []
            for j, spec in enumerate(cfg.pattern):
                per_rep = [
                    _layer_params(cfg, spec,
                                  jax.random.fold_in(ks[3], i * period + j))
                    for i in range(cfg.n_super)]
                stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *per_rep))
            params["scan"] = tuple(stacks)
        for t, spec in enumerate(cfg.tail_specs):
            params[f"tail{t}"] = _layer_params(cfg, spec, ks[4 + t])
        if cfg.encoder is not None:
            params["encoder"] = _encoder_params(cfg, ks[-1])
        if cfg.max_position and cfg.norm == "ln":   # whisper: learned pos
            params["pos_embed"] = (jax.random.normal(
                ks[-1], (min(cfg.max_position, 1 << 16), cfg.d_model))
                * 0.01).astype(jnp.bfloat16)
        return params

    def param_specs(self) -> PyTree:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- forward ----------------------------------------------------------------
    def _embed(self, params: PyTree, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
        return x

    def _extras(self, params: PyTree, extras: Optional[dict],
                batch: int) -> Optional[dict]:
        cfg = self.cfg
        if cfg.encoder is not None:
            assert extras is not None and "frames" in extras
            enc_out = encode(cfg, params["encoder"], extras["frames"],
                             self.kv_chunk, self.unroll_layers,
                             self.inner_unroll)
            return {"src": enc_out}
        if cfg.n_img_tokens:
            assert extras is not None and "img" in extras
            return {"src": extras["img"]}
        return None

    def _logits(self, params: PyTree, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = _norm(cfg, params["final"], x)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head).astype(jnp.float32)
        if self.logits_pspec is not None:
            spec = self.logits_pspec
            if len(spec) > logits.ndim:      # (B,1,V) decode vs (B,S,V)
                spec = type(spec)(*spec[-logits.ndim:])
            logits = jax.lax.with_sharding_constraint(logits, spec)
        return softcap(logits, cfg.final_softcap)

    def forward(self, params: PyTree, tokens: jax.Array,
                extras: Optional[dict] = None, positions=None,
                want_cache: bool = False) -> Tuple[jax.Array, jax.Array, PyTree]:
        """Full-sequence forward. Returns (logits, aux_loss, caches)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens)
        if "pos_embed" in params:
            pe = params["pos_embed"]
            x = x + jax.lax.dynamic_slice_in_dim(pe, 0, S, axis=0)[None]
        positions = jnp.arange(S) if positions is None else positions
        src = self._extras(params, extras, B)
        aux_total = jnp.zeros((), jnp.float32)
        caches: Dict[str, PyTree] = {}

        if cfg.n_super > 0:
            def superblock(x, slices):
                aux_acc = jnp.zeros((), jnp.float32)
                blobs = []
                for spec, lp in zip(cfg.pattern, slices):
                    x, aux, blob = apply_layer_seq(
                        cfg, spec, lp, x, positions, src, self.kv_chunk,
                        want_cache, self.inner_unroll)
                    aux_acc += aux
                    blobs.append(blob)
                return x, (aux_acc, tuple(blobs))

            if self.remat_policy == "dots":
                body = jax.checkpoint(
                    superblock,
                    policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            else:
                body = jax.checkpoint(superblock)

            if self.unroll_layers:
                blob_list = []
                for i in range(cfg.n_super):
                    slices = jax.tree.map(lambda a: a[i], params["scan"])
                    x, (aux_step, blobs) = body(x, slices)
                    aux_total += aux_step
                    blob_list.append(blobs)
                if want_cache:
                    caches["scan"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *blob_list)
                else:
                    caches["scan"] = blob_list[-1]
            else:
                def scan_body(carry, slices):
                    x, aux = carry
                    x, (aux_step, blobs) = body(x, slices)
                    return (x, aux + aux_step), blobs

                (x, aux_total), blob_stacks = jax.lax.scan(
                    scan_body, (x, aux_total), params["scan"])
                caches["scan"] = blob_stacks

        for t, spec in enumerate(cfg.tail_specs):
            fn = jax.checkpoint(
                lambda lp, xx, spec=spec: apply_layer_seq(
                    cfg, spec, lp, xx, positions, src, self.kv_chunk,
                    want_cache, self.inner_unroll))
            x, aux, blob = fn(params[f"tail{t}"], x)
            aux_total += aux
            caches[f"tail{t}"] = blob

        return self._logits(params, x), aux_total, caches

    # -- loss ---------------------------------------------------------------------
    def loss(self, params: PyTree, batch: dict) -> Tuple[jax.Array, dict]:
        """batch: tokens (B,S), labels (B,S) with -100 = ignore, extras."""
        logits, aux, _ = self.forward(params, batch["tokens"],
                                      batch.get("extras"))
        labels = batch["labels"]
        valid = labels >= 0
        safe = jnp.maximum(labels, 0)
        # mask-sum CE (no gather): stays fully shardable over a vocab-sharded
        # logits tensor — take_along_axis would force an all-gather of logits
        lse = jax.nn.logsumexp(logits, axis=-1)
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
        label_logit = jnp.sum(
            jnp.where(viota == safe[..., None], logits, 0.0), axis=-1)
        nll = lse - label_logit
        denom = jnp.maximum(valid.sum(), 1)
        ce = jnp.where(valid, nll, 0.0).sum() / denom
        total = ce + _MOE_AUX_COEF * aux
        return total, {"ce": ce, "aux": aux,
                       "tokens": denom.astype(jnp.float32)}

    # -- decode ----------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int,
                   abstract: bool = False) -> PyTree:
        cfg = self.cfg
        cache: Dict[str, PyTree] = {}
        if cfg.n_super > 0:
            stacks = []
            for spec in cfg.pattern:
                one = init_layer_cache(cfg, spec, batch, cache_len, abstract)
                if abstract:
                    stacked = jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(
                            (cfg.n_super,) + s.shape, s.dtype), one)
                else:
                    stacked = jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            a[None], (cfg.n_super,) + a.shape).copy(), one)
                stacks.append(stacked)
            cache["scan"] = tuple(stacks)
        for t, spec in enumerate(cfg.tail_specs):
            cache[f"tail{t}"] = init_layer_cache(cfg, spec, batch, cache_len,
                                                 abstract)
        return cache

    def decode_step(self, params: PyTree, cache: PyTree, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        """One token for every sequence. tokens: (B, 1); pos: scalar."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if "pos_embed" in params:
            pe = params["pos_embed"]
            L = pe.shape[0]
            x = x + jax.lax.dynamic_slice_in_dim(
                pe, jnp.minimum(pos, L - 1), 1, axis=0)[None]
        new_cache: Dict[str, PyTree] = {}

        if cfg.n_super > 0:
            def scan_body(x, inp):
                slices, cache_slices = inp
                new_blobs = []
                for spec, lp, cb in zip(cfg.pattern, slices, cache_slices):
                    x, nb = apply_layer_step(cfg, spec, lp, cb, x, pos,
                                             self.inner_unroll)
                    new_blobs.append(nb)
                return x, tuple(new_blobs)

            if self.unroll_layers:
                blob_list = []
                for i in range(cfg.n_super):
                    inp = jax.tree.map(lambda a: a[i],
                                       (params["scan"], cache["scan"]))
                    x, blobs = scan_body(x, inp)
                    blob_list.append(blobs)
                new_cache["scan"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *blob_list)
            else:
                x, new_scan = jax.lax.scan(scan_body, x,
                                           (params["scan"], cache["scan"]))
                new_cache["scan"] = new_scan

        for t, spec in enumerate(cfg.tail_specs):
            x, nb = apply_layer_step(cfg, spec, params[f"tail{t}"],
                                     cache[f"tail{t}"], x, pos,
                                     self.inner_unroll)
            new_cache[f"tail{t}"] = nb

        return self._logits(params, x), new_cache

    def prefill(self, params: PyTree, tokens: jax.Array, cache_len: int,
                extras: Optional[dict] = None
                ) -> Tuple[jax.Array, PyTree]:
        """Process a prompt, building a decode cache. Returns (logits, cache).

        Attention K/V computed for the prompt are written into the cache
        (ring-placed for local windows).
        """
        cfg = self.cfg
        B, S = tokens.shape
        logits, _, blobs = self.forward(params, tokens, extras,
                                        want_cache=True)
        cache = self.init_cache(B, cache_len)

        def place(spec: LayerSpec, blob: PyTree, slot: PyTree) -> PyTree:
            out = dict(slot)
            if spec.mix in (ATTN_FULL, ATTN_NONCAUSAL):
                L = slot["k"].shape[-3]
                take = min(S, L)
                for key in ("k", "v"):
                    seq = blob[key][..., S - take:, :, :] if blob[key].ndim == 5 \
                        else blob[key][:, S - take:, :, :]
                    axis = blob[key].ndim - 3
                    if "kscale" in slot:              # int8 cache
                        q, sc = _quantize_kv(seq)
                        out[key] = jax.lax.dynamic_update_slice_in_dim(
                            slot[key], q, 0, axis=axis)
                        out[key + "scale"] = \
                            jax.lax.dynamic_update_slice_in_dim(
                                slot[key + "scale"], sc, 0, axis=axis)
                        continue
                    upd = jax.lax.dynamic_update_slice_in_dim(
                        slot[key], seq.astype(slot[key].dtype), 0,
                        axis=axis)
                    out[key] = upd
            elif spec.mix == ATTN_LOCAL:
                L = slot["k"].shape[-3]
                take = min(S, L)
                positions = jnp.arange(S - take, S)
                slots = jnp.mod(positions, L)
                for key in ("k", "v"):
                    seq = blob[key][..., S - take:, :, :]
                    axis = blob[key].ndim - 3
                    moved = jnp.moveaxis(slot[key], axis, 0)
                    seqm = jnp.moveaxis(seq.astype(slot[key].dtype), axis, 0)
                    out[key] = jnp.moveaxis(moved.at[slots].set(seqm), 0, axis)
            for key in ("h", "conv", "s", "shift_t", "shift_c", "xk", "xv"):
                if key in blob:
                    out[key] = blob[key].astype(slot[key].dtype)
            return out

        new_cache: Dict[str, PyTree] = {}
        if cfg.n_super > 0:
            new_cache["scan"] = tuple(
                place(spec, blobs["scan"][j], cache["scan"][j])
                for j, spec in enumerate(cfg.pattern))
        for t, spec in enumerate(cfg.tail_specs):
            new_cache[f"tail{t}"] = place(spec, blobs[f"tail{t}"],
                                          cache[f"tail{t}"])
        return logits, new_cache
