from .paged import PagePool, SequencePages
from .tiering import TieredKvCache

__all__ = ["PagePool", "SequencePages", "TieredKvCache"]
