"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints store *logical* arrays (runtime/checkpoint.py), so changing the
device count between runs is a restore-time resharding: build the new mesh,
derive PartitionSpecs from the same ShardingRules, and device_put each
leaf. Scale-down after a pod loss and scale-up both reduce to this.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import ShardingRules

PyTree = Any


def state_shardings(cfg, mesh: Mesh, state_specs: PyTree,
                    profile: Optional[str] = None) -> PyTree:
    """NamedSharding tree for a train state on an arbitrary mesh."""
    from jax.sharding import PartitionSpec as P
    rules = ShardingRules(cfg, mesh, profile or "tp")
    pspecs = {
        "params": rules.param_pspecs(state_specs["params"]),
        "opt": {"m": rules.opt_state_pspecs(state_specs["params"]),
                "v": rules.opt_state_pspecs(state_specs["params"]),
                "count": P()},
        "step": P(),
    }
    return rules.to_shardings(pspecs)


def reshard_state(state: PyTree, shardings: PyTree) -> PyTree:
    """Reshard a (restored) logical state onto new shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)
