"""Public policy-scan op: pads, dispatches kernel/oracle, unpads."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANE, policy_scan_pallas
from .ref import N_AGG, policy_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                operands: jax.Array, size_col: int = 0, blocks_col: int = 1,
                valid_col: int = -1, use_kernel: bool = True,
                tile: int = 8 * LANE) -> Tuple[jax.Array, jax.Array]:
    """Evaluate a predicate program over a columnar table + aggregates.

    cols: (n_cols, N) f32. Returns (mask (N,) f32, agg (N_AGG,) f32).
    Rows are padded to the tile size with an all-invalid pad (mask forced 0
    via a validity column the wrapper appends when ``valid_col`` < 0).
    """
    n_cols, n = cols.shape
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    mask, agg = policy_scan_pallas(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col, tile=tile,
        interpret=not _on_tpu()) if use_kernel else policy_scan_ref(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col)
    return mask[:n], agg


def scan_catalog(catalog, expr, now: float, use_kernel: bool = True
                 ) -> Tuple[np.ndarray, dict]:
    """Run a core.policy expression over a Catalog via the kernel path.

    Only numeric/categorical predicates compile to the kernel program;
    glob predicates raise PolicyError (callers fall back to Expr.mask).
    Returns (matching fids, aggregate dict).
    """
    from ...core.policy import KERNEL_COLUMNS, compile_program
    arrays = catalog.arrays()
    ops, colidx, operands = compile_program(expr, catalog.strings, now)
    cols = jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    mask, agg = policy_scan(cols, jnp.asarray(ops), jnp.asarray(colidx),
                            jnp.asarray(operands), size_col=size_col,
                            blocks_col=blocks_col, use_kernel=use_kernel)
    mask_np = np.asarray(mask) > 0.5
    agg_np = np.asarray(agg)
    return arrays["fid"][mask_np], {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
