"""Fid-keyed parallel numpy columns with O(1) upsert/remove.

Shared storage primitive behind the policy engine's incremental match
state (cached match table + age-flip schedule) and the profile cube's
per-shard entry table (bucket membership + age-rollover schedule).

Row addressing is a **sorted base + overlay**: ``bulk_load`` keeps a
sorted copy of the loaded fids so lookups are one vectorized
``searchsorted`` (no million-insert python dict on the bulk path — the
dict build used to dominate full rebuilds); rows upserted after the load
live in a small dict overlay that is consulted only when non-empty.
Rows are tombstoned on removal and the storage compacts itself once the
dead fraction dominates; ``live()`` snapshots the surviving rows in
arbitrary order (callers impose a total order by sorting on content).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np


class FidTable:
    """Fid-keyed parallel numpy columns with O(1) upsert/remove."""

    def __init__(self, specs: Sequence[Tuple[str, type]], cap: int = 1024
                 ) -> None:
        self._specs = tuple(specs)
        self._reset(cap)

    def _reset(self, cap: int) -> None:
        cap = max(1, cap)
        self._fids = np.zeros(cap, dtype=np.int64)
        self._cols = {name: np.zeros(cap, dtype=dt)
                      for name, dt in self._specs}
        self._alive = np.zeros(cap, dtype=bool)
        self._n = 0                               # high-water row count
        self._count = 0                           # live row count
        self._sorted_fids = np.zeros(0, dtype=np.int64)
        self._sorted_rows = np.zeros(0, dtype=np.int64)
        self._overlay: Dict[int, int] = {}        # post-load fid -> row

    def __len__(self) -> int:
        return self._count

    def _grow(self, need: int) -> None:
        cap = len(self._alive)
        while cap < need:
            cap *= 2
        for name in self._cols:
            col = np.zeros(cap, dtype=self._cols[name].dtype)
            col[: self._n] = self._cols[name][: self._n]
            self._cols[name] = col
        fids = np.zeros(cap, dtype=np.int64)
        fids[: self._n] = self._fids[: self._n]
        self._fids = fids
        alive = np.zeros(cap, dtype=bool)
        alive[: self._n] = self._alive[: self._n]
        self._alive = alive

    def _lookup(self, fid_arr: np.ndarray, fid_list: List[int]
                ) -> np.ndarray:
        """Rows for the given fids, -1 where absent/dead. Sorted-base
        search is fully vectorized; the overlay loop only runs when rows
        were upserted since the last bulk load (churn-sized)."""
        rows = np.full(len(fid_list), -1, dtype=np.int64)
        if self._sorted_fids.size:
            pos = np.searchsorted(self._sorted_fids, fid_arr)
            pos_c = np.clip(pos, 0, self._sorted_fids.size - 1)
            base = self._sorted_rows[pos_c]
            hit = (self._sorted_fids[pos_c] == fid_arr) & self._alive[base]
            rows = np.where(hit, base, rows)
        if self._overlay:
            get = self._overlay.get
            for i, f in enumerate(fid_list):
                r = get(f)
                if r is not None:
                    rows[i] = r
        return rows

    def bulk_load(self, fids: np.ndarray, **cols: np.ndarray) -> None:
        """Replace the whole table with the given rows."""
        fids = np.asarray(fids, dtype=np.int64)
        n = len(fids)
        # 25% headroom: the first churn after a bulk load upserts into the
        # overlay without an immediate full grow-copy
        self._reset(max(1024, n + (n >> 2)))
        self._fids[:n] = fids
        for name, vals in cols.items():
            self._cols[name][:n] = vals
        self._alive[:n] = True
        self._n = n
        self._count = n
        order = np.argsort(fids, kind="stable")
        self._sorted_fids = fids[order]
        self._sorted_rows = order

    def upsert_many(self, fids: List[int], **cols: np.ndarray) -> None:
        if not len(fids):
            return
        fid_arr = np.asarray(fids, dtype=np.int64)
        fid_list = fid_arr.tolist()
        pos = self._lookup(fid_arr, fid_list)
        missing = np.nonzero(pos < 0)[0]
        for i in missing.tolist():
            f = fid_list[i]
            # a duplicate fid earlier in this call may have allocated
            # already — reuse its row (last write wins, like the lookup)
            p = self._overlay.get(f)
            if p is None:
                if self._n >= len(self._alive):
                    self._grow(self._n + 1)
                p = self._n
                self._n += 1
                self._count += 1
                self._overlay[f] = p
                self._fids[p] = f
                self._alive[p] = True
            pos[i] = p
        for name, vals in cols.items():
            self._cols[name][pos] = vals

    def remove_many(self, fids: Iterable[int]) -> None:
        fid_list = list(fids)
        if not fid_list:
            return
        pos = self._lookup(np.asarray(fid_list, dtype=np.int64), fid_list)
        for f, p in zip(fid_list, pos.tolist()):
            if p >= 0:
                self._alive[p] = False
                self._count -= 1
                self._overlay.pop(f, None)

    def maybe_compact(self) -> None:
        dead = self._n - self._count
        if dead > 1024 and dead > self._count:
            fids, cols = self.live()
            self.bulk_load(fids, **cols)

    def live(self) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        idx = np.nonzero(self._alive[: self._n])[0]
        return (self._fids[idx].copy(),
                {name: col[idx].copy() for name, col in self._cols.items()})

    def gather(self, fids: Sequence[int]
               ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Row values for specific fids: (present mask, column dict).

        Absent fids read 0 with ``present[i] == False`` — the signed-delta
        analogue of :meth:`Catalog.column_slice`, but over the derived
        table instead of the catalog itself.
        """
        fid_list = list(fids)
        idx = self._lookup(np.asarray(fid_list, dtype=np.int64), fid_list)
        present = idx >= 0
        safe = np.where(present, idx, 0)
        cols = {name: np.where(present, col[safe], col.dtype.type(0))
                for name, col in self._cols.items()}
        return present, cols

    def select_le(self, col: str, val: float) -> np.ndarray:
        """Fids of live rows whose ``col`` value is <= ``val``."""
        sel = self._alive[: self._n] & (self._cols[col][: self._n] <= val)
        return self._fids[: self._n][sel]

    def min_col(self, col: str) -> float:
        """Minimum of ``col`` over live rows (+inf when empty) — lets
        callers cache a due-threshold and skip full scans."""
        vals = self._cols[col][: self._n][self._alive[: self._n]]
        return float(vals.min()) if vals.size else float("inf")
